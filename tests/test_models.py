"""Model zoo tests: per-arch smoke (reduced variants), SSD vs recurrence
oracle, prefill/decode consistency, MoE dispatch invariants, full-config
parameter counts via eval_shape (no allocation)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced
from repro.data.tokens import synthetic_token_batch
from repro.models import (
    decode_step,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)
from repro.models.config import layer_segments, validate
from repro.models.moe import apply_moe, init_moe, moe_capacity
from repro.models.ssm import init_ssm, ssd_full, ssd_reference


def _batch_for(cfg, key, b=2, s=64):
    batch = synthetic_token_batch(key, b, s, cfg.vocab)
    if cfg.frontend:
        k2 = jax.random.fold_in(key, 1)
        batch["frontend_embeds"] = (
            jax.random.normal(k2, (b, cfg.frontend_len, cfg.frontend_dim)) * 0.02
        )
    return batch


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


class TestConfigs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_validate_and_segments(self, arch):
        cfg = get_config(arch)
        validate(cfg)
        segs = layer_segments(cfg)
        n = sum(reps * sum(1 for s in unit if s.kind != "shared_attn") for unit, reps in segs)
        assert n == cfg.num_layers

    def test_full_param_counts_match_model_cards(self):
        """eval_shape the FULL configs (no allocation) and check total
        parameter counts are in the right ballpark of the model cards."""
        expected = {  # (low, high) in billions
            "yi_9b": (8.0, 10.0),
            "starcoder2_7b": (6.0, 8.5),
            "internlm2_20b": (17.0, 22.0),
            "deepseek_v3_671b": (600.0, 720.0),
            "grok1_314b": (280.0, 340.0),
            "gemma3_12b": (10.0, 14.0),
            "mamba2_1p3b": (1.0, 1.6),
            "phi3_vision_4p2b": (3.5, 4.5),
            "whisper_large_v3": (1.2, 2.0),
            "zamba2_1p2b": (1.0, 1.6),
        }
        for arch, (lo, hi) in expected.items():
            cfg = get_config(arch)
            shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
            total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes)) / 1e9
            assert lo <= total <= hi, f"{arch}: {total:.2f}B not in [{lo},{hi}]"


@pytest.mark.slow
class TestSmokeAllArchs:
    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_reduced_train_step(self, arch, key):
        """One forward+backward on the reduced variant: finite loss,
        finite grads, correct logit shapes."""
        cfg = reduced(get_config(arch))
        params = init_params(cfg, key)
        batch = _batch_for(cfg, key)

        def loss_only(p):
            return loss_fn(p, cfg, batch)[0]

        loss, grads = jax.jit(jax.value_and_grad(loss_only))(params)
        assert np.isfinite(float(loss))
        leaves = jax.tree.leaves(grads)
        assert all(np.isfinite(np.asarray(g)).all() for g in leaves)

    @pytest.mark.parametrize("arch", ARCH_IDS)
    def test_reduced_decode_shapes(self, arch, key):
        cfg = reduced(get_config(arch))
        params = init_params(cfg, key)
        b, s_max = 2, 32
        enc_len = cfg.frontend_len if cfg.is_encdec() else 0
        caches = init_cache(cfg, b, s_max, enc_len=enc_len)
        if cfg.is_encdec():
            # seed cross-attn cache from a prefill
            batch = _batch_for(cfg, key, b=b, s=8)
            _, pcaches = prefill(params, cfg, batch)
        token = jnp.zeros((b, 1), jnp.int32)
        logits, caches = jax.jit(
            lambda p, t, c: decode_step(p, cfg, t, c, jnp.asarray(4, jnp.int32))
        )(params, token, caches)
        assert logits.shape == (b, 1, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.slow
class TestDecodeConsistency:
    @pytest.mark.parametrize(
        "arch", ["yi_9b", "gemma3_12b", "deepseek_v3_671b", "mamba2_1p3b", "zamba2_1p2b"]
    )
    def test_prefill_then_decode_matches_full_forward(self, arch, key):
        """Teacher-forced decode must reproduce the full-sequence logits:
        run s steps of decode_step from an empty cache and compare with
        the one-shot forward at each position."""
        cfg = reduced(get_config(arch))
        params = init_params(cfg, key)
        b, s = 1, 8
        batch = _batch_for(cfg, key, b=b, s=s)
        tokens = batch["tokens"]

        # full forward logits at every position
        full_logits, _ = prefill(params, cfg, {**batch, "tokens": tokens})
        # prefill returns only last position; recompute via loss path
        from repro.models.model import _embed, _logits
        from repro.models.transformer import forward_stack

        x = _embed(params, cfg, tokens, batch)
        positions = jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32), (b, s))
        x, _, _ = forward_stack(
            params["decoder"], layer_segments(cfg), cfg, x, positions,
            shared_params=params.get("shared_attn"),
        )
        ref = np.asarray(_logits(params, cfg, x))  # (b, s, V)

        caches = init_cache(cfg, b, s)
        outs = []
        for i in range(s):
            logits, caches = decode_step(
                params, cfg, tokens[:, i : i + 1], caches, jnp.asarray(i, jnp.int32)
            )
            outs.append(np.asarray(logits[:, 0, :]))
        got = np.stack(outs, axis=1)
        np.testing.assert_allclose(got, ref, rtol=2e-2, atol=2e-2)


class TestSSD:
    def test_chunked_matches_recurrence(self, key):
        cfg = reduced(get_config("mamba2_1p3b"))
        p = init_ssm(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 2), (2, 64, cfg.d_model)) * 0.1
        y_chunked, (state, _) = ssd_full(p, x, cfg)
        y_ref = ssd_reference(p, x, cfg)
        np.testing.assert_allclose(
            np.asarray(y_chunked), np.asarray(y_ref), rtol=5e-3, atol=5e-3
        )

    def test_prefill_state_continues_decode(self, key):
        """State handed from ssd_full must continue the recurrence
        identically to running the whole sequence recurrently."""
        from repro.models.ssm import ssd_decode

        cfg = reduced(get_config("mamba2_1p3b"))
        p = init_ssm(key, cfg, jnp.float32)
        x = jax.random.normal(jax.random.fold_in(key, 3), (1, 40, cfg.d_model)) * 0.1
        s_pre = 32
        _, (state, conv_tail) = ssd_full(p, x[:, :s_pre, :], cfg)
        outs = []
        st, cv = state, conv_tail
        for i in range(s_pre, 40):
            o, st, cv = ssd_decode(p, x[:, i : i + 1, :], st, cv, cfg)
            outs.append(np.asarray(o))
        ref = np.asarray(ssd_reference(p, x, cfg))[:, s_pre:, :]
        got = np.concatenate(outs, axis=1)
        np.testing.assert_allclose(got, ref, rtol=5e-3, atol=5e-3)


class TestMoE:
    def test_capacity_rounding(self):
        cfg = get_config("deepseek_v3_671b")
        c = moe_capacity(cfg, 1024)
        assert c % 8 == 0 and c >= 1024 * 8 * 1.25 / 256

    def test_moe_output_finite_and_shaped(self, key):
        cfg = reduced(get_config("grok1_314b"))
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (2, 16, cfg.d_model)) * 0.1
        out, aux = apply_moe(p, x, cfg)
        assert out.shape == x.shape
        assert np.isfinite(np.asarray(out)).all()
        assert float(aux) >= 0.0

    def test_moe_respects_capacity_drop(self, key):
        """With capacity_factor so small every expert overflows, output
        must be (near) zero for dropped tokens, not NaN."""
        import dataclasses

        cfg = dataclasses.replace(reduced(get_config("grok1_314b")), capacity_factor=0.01)
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (1, 64, cfg.d_model)) * 0.1
        out, _ = apply_moe(p, x, cfg)
        assert np.isfinite(np.asarray(out)).all()

    def test_router_gradient_flows(self, key):
        cfg = reduced(get_config("deepseek_v3_671b"))
        p = init_moe(key, cfg, jnp.float32)
        x = jax.random.normal(key, (1, 16, cfg.d_model)) * 0.1

        def f(pp):
            out, aux = apply_moe(pp, x, cfg)
            return jnp.sum(out**2) + aux

        g = jax.grad(f)(p)
        assert float(jnp.sum(jnp.abs(g["router"]))) > 0.0
