"""Unit + property tests for the TMSN core (stopping rule, ESS, protocol)."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    StoppingRuleParams,
    accepts,
    effective_sample_size,
    improves,
    stopping_rule_fires,
    stopping_threshold,
)
from repro.core.ess import expected_sample_fraction
from repro.core.stopping import hoeffding_threshold

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


class TestESS:
    def test_uniform_weights(self):
        w = jnp.ones(100)
        assert float(effective_sample_size(w)) == pytest.approx(100.0)

    def test_k_of_n(self):
        # paper's motivating example: k weight-1 examples among zeros
        w = jnp.concatenate([jnp.ones(10), jnp.zeros(90)])
        assert float(effective_sample_size(w)) == pytest.approx(10.0)

    def test_scale_invariance(self):
        w = jnp.array([0.5, 1.5, 2.0, 0.1])
        a = float(effective_sample_size(w))
        b = float(effective_sample_size(w * 37.0))
        assert a == pytest.approx(b, rel=1e-5)

    def test_all_zero(self):
        assert float(effective_sample_size(jnp.zeros(5))) == 0.0

    if HAVE_HYPOTHESIS:

        @settings(deadline=None, max_examples=50)
        @given(
            st.lists(st.floats(min_value=0.0, max_value=1e3), min_size=1, max_size=64)
        )
        def test_bounds(self, ws):
            """1 <= n_eff <= n for any nonneg weights with some mass."""
            w = jnp.asarray(ws, jnp.float32)
            ess = float(effective_sample_size(w))
            if float(jnp.sum(w)) > 0:
                assert 1.0 - 1e-3 <= ess <= len(ws) + 1e-3
            else:
                assert ess == 0.0

    def test_expected_sample_fraction(self):
        w = jnp.array([1.0, 1.0, 2.0])
        assert float(expected_sample_fraction(w)) == pytest.approx((4 / 3) / 2)


class TestStoppingRule:
    def test_no_evidence_never_fires(self):
        p = StoppingRuleParams()
        thr = stopping_threshold(jnp.asarray(0.0), jnp.asarray(0.0), p)
        assert not np.isfinite(float(thr))

    def test_strong_edge_fires(self):
        # perfect rule: m = W after many unit-weight examples
        p = StoppingRuleParams(C=1.0, delta=1e-6)
        n = 2000.0
        fires, signs, _ = stopping_rule_fires(
            jnp.asarray([n]), jnp.asarray(n), jnp.asarray(n), 0.1, p
        )
        assert bool(fires[0]) and float(signs[0]) == 1.0

    def test_negated_rule_fires_negative(self):
        p = StoppingRuleParams()
        n = 2000.0
        fires, signs, _ = stopping_rule_fires(
            jnp.asarray([-n]), jnp.asarray(n), jnp.asarray(n), 0.1, p
        )
        assert bool(fires[0]) and float(signs[0]) == -1.0

    def test_zero_edge_does_not_fire(self):
        p = StoppingRuleParams()
        fires, _, _ = stopping_rule_fires(
            jnp.asarray([0.0]), jnp.asarray(1000.0), jnp.asarray(1000.0), 0.0, p
        )
        assert not bool(fires[0])

    def test_soundness_monte_carlo(self):
        """Under the null (true edge = 0), the rule should essentially
        never certify an edge > gamma. Empirical false-fire rate over
        random walks must be small."""
        rng = np.random.default_rng(0)
        p = StoppingRuleParams(C=1.0, delta=1e-3)
        n_trials, horizon, gamma = 200, 4000, 0.05
        false_fires = 0
        for _ in range(n_trials):
            x = rng.choice([-1.0, 1.0], size=horizon)  # unit weights, zero edge
            m = np.cumsum(x)
            W = np.arange(1, horizon + 1, dtype=np.float64)
            V = W.copy()
            M = m - 2 * gamma * W
            thr = np.asarray(
                stopping_threshold(jnp.asarray(V, jnp.float32), jnp.asarray(M, jnp.float32), p)
            )
            # only a fire on the POSITIVE side is a false certification
            if np.any(M > thr):
                false_fires += 1
        assert false_fires <= 10  # <= 5% empirically (delta=1e-3 nominal)

    def test_tightness_vs_hoeffding(self):
        """The iterated-log rule should be tighter than the union-bound
        Hoeffding rule at large t (the reason the paper uses it)."""
        p = StoppingRuleParams(C=1.0, delta=1e-6)
        V = jnp.asarray(1e6)
        t = jnp.asarray(1e6)
        il = float(stopping_threshold(V, jnp.asarray(1000.0), p))
        hf = float(hoeffding_threshold(V, t, p))
        assert il < hf

    def test_true_edge_fires_within_sample_budget(self):
        """A rule with true edge 2*gamma fires well before n ~ 1/gamma^2 * log."""
        rng = np.random.default_rng(1)
        p = StoppingRuleParams(C=1.0, delta=1e-3)
        gamma = 0.1  # correlation 0.4
        horizon = 40000
        x = rng.choice([-1.0, 1.0], p=[0.3, 0.7], size=horizon)  # correlation 0.4
        m = np.cumsum(x)
        W = np.arange(1, horizon + 1, dtype=np.float64)
        M = m - 2 * gamma * W
        thr = np.asarray(
            stopping_threshold(jnp.asarray(W, jnp.float32), jnp.asarray(M, jnp.float32), p)
        )
        fire_at = np.argmax(M > thr)
        assert M[fire_at] > thr[fire_at]
        assert fire_at < horizon / 4  # fires early, not at the bitter end


class TestProtocol:
    def test_improves_gap(self):
        assert improves(1.0, 0.8, 0.1)
        assert not improves(1.0, 0.95, 0.1)
        assert not improves(1.0, 1.2, 0.0)

    def test_accepts_is_strict_gap(self):
        assert accepts(0.5, 0.3, 0.1)
        assert not accepts(0.5, 0.45, 0.1)
        # never accept an equal-or-worse certificate
        assert not accepts(0.5, 0.5, 0.0)

    def test_monotone_descent_invariant(self):
        """Interleaving improves/accepts can only lower a certificate."""
        rng = np.random.default_rng(2)
        local = 1.0
        for _ in range(1000):
            incoming = float(rng.uniform(0, 2))
            if accepts(local, incoming, 0.05):
                assert incoming < local
                local = incoming


class TestTrafficCounters:
    """The shared counter reduction incl. the ICI/DCN tier split (the
    pod-mesh engine's per-shard partials land here; the derived halves
    must stay consistent with the totals by construction)."""

    def test_from_shards_reduces_partials(self):
        from repro.core.result import TrafficCounters

        t = TrafficCounters.from_shards(
            sent=np.array([3, 4]), accepted=np.array([1, 1]),
            discarded=np.array([0, 2]), payload_bytes=8,
            sent_dcn=np.array([2, 1]),
        )
        assert (t.sent, t.accepted, t.discarded) == (7, 2, 2)
        assert t.bytes_broadcast == 7 * 8
        assert (t.sent_dcn, t.sent_ici) == (3, 4)
        assert t.bytes_dcn == 3 * 8

    def test_single_tier_scalars_report_zero_dcn(self):
        from repro.core.result import TrafficCounters

        t = TrafficCounters.from_shards(
            sent=10, accepted=4, discarded=6, payload_bytes=16
        )
        assert t.sent_dcn == 0 and t.bytes_dcn == 0
        assert t.sent_ici == t.sent == 10
