"""Conformance suite for the first-class worker API
(:mod:`repro.core.worker`) and the engine-hosted TMSN-SGD worker.

Three layers:

  * a reusable contract harness, run against BOTH production workers
    (``BatchedSparrowWorker`` — boosting, every optional hook defined —
    and ``BatchedSGDWorker`` — transformer training, NO optional hook):
    state/certificate shapes, masked-out workers bitwise unchanged at
    zero cost, adopt-batch identity where ``take`` is False (what makes
    the engine's ``lax.cond`` skip sound), certificate monotonicity
    under random accept-gated scan/adopt sequences;
  * the optional-hook machinery itself: resample-hook detection,
    the shared ``export_payload_rows`` fallback, and the
    ``payload_bytes`` resolution order — including the pin that
    Sparrow's hand-written byte count matches the value derived from
    its exported pytree via ``jax.eval_shape`` (the derived path cannot
    drift from reality; the hand path could);
  * substrate equivalence: both workers under ``TMSNEngine`` against
    the dense delay-1 oracle (``repro.core.tmsn_sgd.oracle_run``) on
    uniform speed / zero latency, and the SGD worker across every
    sharded leg — dense, gated, sparse in-flight, pod mesh — on 8
    forced host devices (single-device runs skip those).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.boosting import BatchedSparrowWorker, SparrowConfig
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import empty_model, model_payload_bytes
from repro.core.engine import EngineConfig, MembershipPlan, TMSNEngine, make_engine
from repro.core.engine_sharded import sharded_engine_available
from repro.core.sgd_worker import lm_sgd_worker
from repro.core.tmsn_sgd import TMSNSGDConfig, oracle_run
from repro.core.worker import (
    BatchedTMSNWorker,
    export_payload_rows,
    has_resample_hooks,
    payload_bytes_from_export,
    resolve_payload_bytes,
)
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split
from repro.launch.mesh import make_worker_mesh
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig

W = 4  # worker count every harness case uses


# ---------------------------------------------------------------------------
# fixtures: one instance of each production worker, sized for CI
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def sparrow_worker():
    xb, y, _ = make_splice_like(SpliceConfig(n=4_000, d=12, num_bins=8, seed=3))
    xtr, ytr, _, _ = train_test_split(xb, y)
    cfg = SparrowConfig(
        sample_size=256,
        capacity=16,
        scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25),
        n_workers=W,
    )
    return BatchedSparrowWorker(xtr, ytr, cfg)


TINY_ARCH = ArchConfig(
    name="tiny-contract",
    arch_type="llama",
    num_layers=1,
    d_model=16,
    num_heads=2,
    num_kv_heads=2,
    d_ff=32,
    vocab=64,
    remat=False,
    compute_dtype="float32",
)


def _sgd_worker(local_steps=2, ema=0.8, width_coef=1.0):
    return lm_sgd_worker(
        TINY_ARCH,
        AdamWConfig(lr=1e-2),
        TMSNSGDConfig(local_steps=local_steps, ema=ema, width_coef=width_coef),
        batch_size=2,
        seq=8,
    )


@pytest.fixture(scope="module")
def sgd_worker():
    return _sgd_worker()


@pytest.fixture(params=["sparrow", "sgd"])
def worker(request, sparrow_worker, sgd_worker):
    return sparrow_worker if request.param == "sparrow" else sgd_worker


# ---------------------------------------------------------------------------
# contract harness (parametrized over both production workers)
# ---------------------------------------------------------------------------


def _leaves(tree):
    return jax.tree_util.tree_leaves(tree)


def _assert_rows_equal(tree_a, tree_b, rows):
    for a, b in zip(_leaves(tree_a), _leaves(tree_b)):
        np.testing.assert_array_equal(np.asarray(a)[rows], np.asarray(b)[rows])


class TestWorkerContract:
    def test_state_and_certificate_shapes(self, worker):
        state = worker.init_batch(W, seed=0)
        for leaf in _leaves(state):
            assert leaf.shape[:1] == (W,), f"leaf {leaf.shape} lacks the (W,) axis"
        certs = worker.certificates(state)
        assert certs.shape == (W,) and certs.dtype == jnp.float32
        for leaf in _leaves(worker.export_models(state)):
            assert leaf.shape[:1] == (W,)
        state2, cost, fired = worker.scan_round(state, jnp.ones((W,), bool))
        assert cost.shape == (W,) and fired.shape == (W,)
        assert fired.dtype == jnp.bool_

    def test_masked_rows_unchanged_at_zero_cost(self, worker):
        state = worker.init_batch(W, seed=0)
        # a couple of warmup segments so masked rows carry real history
        state, _, _ = worker.scan_round(state, jnp.ones((W,), bool))
        mask = jnp.asarray([True, False, True, False])
        new, cost, fired = worker.scan_round(state, mask)
        off = np.asarray(~mask)
        _assert_rows_equal(new, state, off)
        np.testing.assert_array_equal(np.asarray(cost)[off], 0.0)
        np.testing.assert_array_equal(np.asarray(fired)[off], False)

    def test_adopt_identity_where_take_false(self, worker):
        """The oracle calls adopt_batch unconditionally while the engine
        lax.cond-skips it — they only agree if take=False rows (and the
        all-False call) are the identity at zero cost."""
        state = worker.init_batch(W, seed=0)
        state, _, _ = worker.scan_round(state, jnp.ones((W,), bool))
        models = worker.export_models(state)
        donors = jnp.asarray([1, 2, 3, 0])
        in_models = jax.tree_util.tree_map(lambda a: a[donors], models)
        in_certs = worker.certificates(state)[donors] - 1.0
        new, cost = worker.adopt_batch(
            state, in_models, in_certs, jnp.zeros((W,), bool)
        )
        _assert_rows_equal(new, state, np.arange(W))
        np.testing.assert_array_equal(np.asarray(cost), 0.0)

    def test_certificates_monotone_under_random_protocol(self, worker):
        """Random masked segments interleaved with accept-gated adopts:
        the certificate vector must never increase (the property every
        gated-gossip / pod-mesh equivalence argument leans on)."""
        rng = np.random.default_rng(7)
        state = worker.init_batch(W, seed=1)
        certs = np.asarray(worker.certificates(state))
        for _ in range(8):
            mask = jnp.asarray(rng.random(W) < 0.7)
            state, _, _ = worker.scan_round(state, mask)
            after = np.asarray(worker.certificates(state))
            assert np.all(after <= certs + 1e-7), (after, certs)
            certs = after
            # accept-gated adopt from a random donor permutation
            donors = jnp.asarray(rng.permutation(W))
            models = worker.export_models(state)
            in_models = jax.tree_util.tree_map(lambda a: a[donors], models)
            in_certs = jnp.asarray(certs, jnp.float32)[donors]
            take = (
                jnp.asarray(rng.random(W) < 0.5)
                & (in_certs < jnp.asarray(certs, jnp.float32))
            )
            state, _ = worker.adopt_batch(state, in_models, in_certs, take)
            after = np.asarray(worker.certificates(state))
            assert np.all(after <= certs + 1e-7), (after, certs)
            certs = after


class TestAdoptAfterJoin:
    """Elastic-membership contract case: a spare row that never scanned
    (masked since init) adopting the cluster's best snapshot on its join
    round must be identity-at-zero-cost for every OTHER row — the same
    guarantee the engine's take-gated adopt leans on, now exercised from
    a completely cold state for BOTH production workers."""

    def test_adopt_into_fresh_spare_row_is_identity_elsewhere(self, worker):
        state = worker.init_batch(W, seed=0)
        # the members make real progress while the spare (last row) is
        # masked out — its state stays exactly as init_batch left it
        member_mask = jnp.asarray([True] * (W - 1) + [False])
        for _ in range(3):
            state, _, _ = worker.scan_round(state, member_mask)
        certs = worker.certificates(state)
        best = int(np.argmin(np.asarray(certs)[: W - 1]))
        donors = jnp.full((W,), best, jnp.int32)
        in_models = jax.tree_util.tree_map(
            lambda a: a[donors], worker.export_models(state)
        )
        in_certs = certs[donors]
        take = jnp.asarray([False] * (W - 1) + [True])  # only the joiner
        new, cost = worker.adopt_batch(state, in_models, in_certs, take)
        _assert_rows_equal(new, state, np.arange(W - 1))
        np.testing.assert_array_equal(np.asarray(cost)[: W - 1], 0.0)
        # the joiner now reports the adopted snapshot's certificate
        np.testing.assert_array_equal(
            np.asarray(worker.certificates(new))[W - 1], np.asarray(certs)[best]
        )

    def test_engine_join_run_both_workers(self, worker):
        """End-to-end: a spare activated mid-run under the real engine —
        the run completes, counts the join, and stays monotone."""
        res = TMSNEngine(
            worker,
            _engine_cfg(
                spare_slots=1,
                # k=2: early enough that the slow Sparrow joiner still
                # fires a post-activation improvement within ROUNDS
                membership=MembershipPlan(joins=((2, W - 1),)),
            ),
        ).run()
        assert res.workers_joined == 1
        assert res.rounds == ROUNDS
        per_worker = {}
        for _, wid, cert in res.history:
            prev = per_worker.get(wid)
            assert prev is None or cert <= prev + 1e-7
            per_worker[wid] = cert
        # the joiner shows up in post-activation history
        assert any(wid == W - 1 and t > 0 for t, wid, _ in res.history)


# ---------------------------------------------------------------------------
# optional-hook machinery
# ---------------------------------------------------------------------------


class TestOptionalHooks:
    def test_resample_hook_detection(self, sparrow_worker, sgd_worker):
        assert has_resample_hooks(sparrow_worker)
        assert not has_resample_hooks(sgd_worker)
        # an engine built over a hook-less worker drops the branch
        eng = TMSNEngine(sgd_worker, EngineConfig(n_workers=W, max_rounds=1))
        assert eng._has_resample is False
        eng = TMSNEngine(
            sparrow_worker, EngineConfig(n_workers=W, max_rounds=1)
        )
        assert eng._has_resample is True

    def test_sparrow_hand_payload_bytes_matches_derived(self, sparrow_worker):
        """Satellite 2: the hand-written byte count and the eval_shape
        derivation must agree — the derived value is ground truth."""
        hand = sparrow_worker.payload_bytes()
        derived = payload_bytes_from_export(sparrow_worker, W, seed=0)
        assert hand == derived
        assert hand == model_payload_bytes(
            empty_model(sparrow_worker.config.capacity)
        )
        # resolution order: a defined hook wins (even when equal here)
        assert resolve_payload_bytes(sparrow_worker, W, seed=0) == hand

    def test_sgd_payload_bytes_derived(self, sgd_worker):
        """No hook on the SGD worker: resolution falls through to the
        derived value — the per-worker params footprint."""
        derived = resolve_payload_bytes(sgd_worker, W, seed=0)
        state = sgd_worker.init_batch(W, seed=0)
        params_bytes = sum(
            int(np.prod(a.shape[1:])) * a.dtype.itemsize
            for a in _leaves(sgd_worker.export_models(state))
        )
        assert derived == params_bytes > 0

    def test_export_payload_rows_fallback(self, sparrow_worker, sgd_worker):
        rows = jnp.asarray([2, 0])
        for w in (sparrow_worker, sgd_worker):
            state = w.init_batch(W, seed=0)
            got = export_payload_rows(w, state, rows)
            want = jax.tree_util.tree_map(
                lambda a: a[rows], w.export_models(state)
            )
            for g, x in zip(_leaves(got), _leaves(want)):
                np.testing.assert_array_equal(np.asarray(g), np.asarray(x))

    def test_protocol_default_bodies_inheritable(self):
        """A worker subclassing the protocol inherits working no-op
        resample hooks and the indexing payload-rows fallback."""

        class Minimal(BatchedTMSNWorker):
            def init_batch(self, n_workers, seed):
                return {"c": jnp.zeros((n_workers,), jnp.float32)}

            def scan_round(self, state, mask):
                c = state["c"] - mask.astype(jnp.float32)
                return {"c": c}, mask.astype(jnp.float32), mask

            def certificates(self, state):
                return state["c"]

            def export_models(self, state):
                return {"m": state["c"]}

            def adopt_batch(self, state, models, certs, take):
                return (
                    {"c": jnp.where(take, certs, state["c"])},
                    jnp.zeros_like(state["c"]),
                )

        w = Minimal()
        state = w.init_batch(3, 0)
        assert not np.any(np.asarray(w.needs_resample(state)))
        same, cost = w.resample_round(state, jnp.ones((3,), bool))
        np.testing.assert_array_equal(np.asarray(same["c"]), np.asarray(state["c"]))
        np.testing.assert_array_equal(np.asarray(cost), 0.0)
        rows = export_payload_rows(w, state, jnp.asarray([1]))
        assert rows["m"].shape == (1,)
        with pytest.raises(NotImplementedError):
            w.payload_bytes()
        # the default (inherited, not overridden) payload_bytes does NOT
        # shadow derivation — resolve falls through to eval_shape
        assert resolve_payload_bytes(w, 3, seed=0) == 4

    def test_engine_protocol_home(self):
        """The redesign's point: engine.py consumes the contract, it no
        longer defines it (and never references a concrete worker)."""
        import inspect

        import repro.core.engine as engine_mod
        import repro.core.worker as worker_mod

        assert inspect.getmodule(BatchedTMSNWorker) is worker_mod
        src = inspect.getsource(engine_mod)
        assert "class BatchedTMSNWorker" not in src
        assert "parrow" not in src  # no Sparrow-specific types in engines
        assert "parrow" not in inspect.getsource(
            __import__("repro.core.engine_sharded", fromlist=["x"])
        )


# ---------------------------------------------------------------------------
# substrate equivalence: engines vs the dense delay-1 oracle
# ---------------------------------------------------------------------------

ROUNDS = 8


def _engine_cfg(**kw):
    base = dict(
        n_workers=W,
        eps=0.0,
        max_rounds=ROUNDS,
        delay_rounds=1,
        seed=0,
        fault_spec="",  # oracle comparisons: chaos CI leg must not steer them
    )
    base.update(kw)
    return EngineConfig(**base)


class TestEngineOracleEquivalence:
    def test_engine_matches_oracle(self, worker):
        """Uniform speed, delay 1, no failures: the round engine must be
        bit-identical to the worker-level synchronous oracle — for BOTH
        production workers."""
        orc = oracle_run(worker, W, ROUNDS, eps=0.0, seed=0)
        res = TMSNEngine(worker, _engine_cfg()).run()
        np.testing.assert_array_equal(
            np.asarray(res.final_certificates, np.float32), orc.certs
        )
        # oracle history is monotone per worker
        assert np.all(np.diff(orc.history, axis=0) <= 1e-7)

    def test_sgd_engine_history_monotone(self, sgd_worker):
        res = TMSNEngine(sgd_worker, _engine_cfg()).run()
        per_worker = {}
        for _, wid, cert in res.history:
            prev = per_worker.get(wid)
            assert prev is None or cert <= prev + 1e-7
            per_worker[wid] = cert
        assert res.rounds == ROUNDS
        assert res.bytes_broadcast > 0  # derived payload_bytes flowed in


needs_devices = pytest.mark.skipif(
    not sharded_engine_available(),
    reason="sharded engine needs >=2 devices "
    "(CI forces 8 via --xla_force_host_platform_device_count)",
)


@needs_devices
class TestShardedSGDWorker:
    """The acceptance criterion: BatchedSGDWorker completes runs under
    ShardedTMSNEngine in dense AND gated modes, plus a pod-mesh leg and
    the sparse in-flight state, all bit-identical to the oracle."""

    W8 = 8

    @pytest.fixture(scope="class")
    def oracle8(self, sgd_worker):
        return oracle_run(sgd_worker, self.W8, ROUNDS, eps=0.0, seed=0)

    def _run(self, sgd_worker, mesh, **kw):
        cfg = EngineConfig(
            n_workers=self.W8,
            eps=0.0,
            max_rounds=ROUNDS,
            delay_rounds=1,
            seed=0,
            fault_spec="",
            mesh=mesh,
            **kw,
        )
        return make_engine(sgd_worker, cfg).run()

    def _mesh(self):
        n = len(jax.devices())
        while self.W8 % n:
            n -= 1
        return make_worker_mesh(n)

    def test_dense(self, sgd_worker, oracle8):
        res = self._run(sgd_worker, self._mesh(), gossip_mode="dense")
        np.testing.assert_array_equal(
            np.asarray(res.final_certificates, np.float32), oracle8.certs
        )

    def test_gated(self, sgd_worker, oracle8):
        res = self._run(
            sgd_worker, self._mesh(), gossip_mode="gated", gossip_top_k=1
        )
        np.testing.assert_array_equal(
            np.asarray(res.final_certificates, np.float32), oracle8.certs
        )
        assert res.gossip_mode == "gated"

    def test_sparse_inflight(self, sgd_worker, oracle8):
        res = self._run(
            sgd_worker,
            self._mesh(),
            gossip_mode="dense",
            inflight_capacity=self.W8,
        )
        np.testing.assert_array_equal(
            np.asarray(res.final_certificates, np.float32), oracle8.certs
        )
        assert res.messages_evicted == 0  # capacity covered: exact run

    def test_pod_mesh(self, sgd_worker, oracle8):
        if len(jax.devices()) < 4:
            pytest.skip("pod mesh needs >=4 devices")
        mesh = make_worker_mesh(pods=2)
        # k=1/top_k=1 is the bit-exact cross-pod regime (docs/config.md)
        res = self._run(
            sgd_worker,
            mesh,
            gossip_mode="dense",
            cross_pod_every_k=1,
            cross_pod_top_k=1,
        )
        np.testing.assert_array_equal(
            np.asarray(res.final_certificates, np.float32), oracle8.certs
        )

    def test_final_cert_improves_from_init(self, sgd_worker, oracle8):
        """The run actually trains: no certificate above its round-0
        estimate, and somebody made strict progress (the best worker can
        plateau exactly at its own adopted broadcast, so per-worker
        strictness would overclaim)."""
        assert np.all(np.isfinite(oracle8.certs))
        assert np.all(oracle8.certs <= oracle8.history[0] + 1e-7)
        assert np.min(oracle8.certs) < np.max(oracle8.history[0])
