"""Property-based tests (hypothesis) for system invariants across
layers: RoPE/RMSNorm identities, attention masking, sharding-fit rules,
the exp-loss potential recursion, the engine's worst-first eviction
order, and the sparse-control/dense-control protocol equivalence."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core.engine import EngineConfig, _empty_queue, _queue_push, make_engine
from repro.launch.sharding import fit_spec
from repro.models.layers import apply_rope, rms_norm, rope_freqs, softmax_cross_entropy
from test_sharded_engine import ShardableToyWorker

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYP = True
except ImportError:  # pragma: no cover
    HAVE_HYP = False

    # No-op stand-ins so the @settings/@given decorators (which execute
    # at import time) don't blow up collection; the module-level skipif
    # below is what actually skips the tests.
    def settings(*args, **kwargs):
        return lambda f: f

    def given(*args, **kwargs):
        return lambda f: f

    class _NullStrategies:
        def __getattr__(self, name):
            return lambda *args, **kwargs: None

    st = _NullStrategies()

pytestmark = pytest.mark.skipif(not HAVE_HYP, reason="hypothesis not installed")


@settings(deadline=None, max_examples=25)
@given(st.integers(min_value=1, max_value=8), st.integers(min_value=2, max_value=16))
def test_rope_preserves_norm(b, s):
    """Rotations never change vector norms."""
    key = jax.random.PRNGKey(b * 31 + s)
    x = jax.random.normal(key, (b, s, 2, 8))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    cos, sin = rope_freqs(pos, 8, 10_000.0)
    y = apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1),
        rtol=1e-4,
    )


@settings(deadline=None, max_examples=25)
@given(st.integers(min_value=2, max_value=64))
def test_rope_relative_property(d2):
    """<rope(q,i), rope(k,j)> depends only on i-j."""
    d = d2 * 2
    key = jax.random.PRNGKey(d)
    q = jax.random.normal(key, (1, 1, 1, d))
    k = jax.random.normal(jax.random.fold_in(key, 1), (1, 1, 1, d))

    def dot_at(i, j):
        ci, si = rope_freqs(jnp.asarray([[i]]), d, 10_000.0)
        cj, sj = rope_freqs(jnp.asarray([[j]]), d, 10_000.0)
        return float(jnp.sum(apply_rope(q, ci, si) * apply_rope(k, cj, sj)))

    assert dot_at(5, 3) == pytest.approx(dot_at(9, 7), rel=1e-3, abs=1e-4)


@settings(deadline=None, max_examples=30)
@given(st.floats(min_value=0.5, max_value=100.0))
def test_rms_norm_scale_invariance(scale):
    """Invariance is exact up to the eps regulariser."""
    x = jnp.asarray([[1.0, -2.0, 3.0, 0.5]])
    g = jnp.zeros((4,))
    a = rms_norm(x, g)
    b = rms_norm(x * scale, g)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-2)


@settings(deadline=None, max_examples=30)
@given(
    st.integers(min_value=1, max_value=5),
    st.integers(min_value=2, max_value=50),
)
def test_cross_entropy_bounds(b, v):
    """0 <= CE; CE(uniform logits) == log V."""
    logits = jnp.zeros((b, 3, v))
    labels = jnp.zeros((b, 3), jnp.int32)
    mask = jnp.ones((b, 3))
    ce = float(softmax_cross_entropy(logits, labels, mask))
    assert ce == pytest.approx(np.log(v), rel=1e-5)


@settings(deadline=None, max_examples=50)
@given(
    st.lists(st.integers(min_value=1, max_value=8192), min_size=1, max_size=4),
    st.sampled_from([("data",), ("model",), ("data", "model")]),
)
def test_fit_spec_always_valid(shape, axes):
    """fit_spec output always divides evenly (the jit contract)."""
    sizes = {"data": 16, "model": 16, "pod": 2}
    spec = P(*(axes[i % len(axes)] for i in range(len(shape))))
    fitted = fit_spec(spec, tuple(shape), sizes)
    for dim, part in zip(shape, tuple(fitted) + (None,) * len(shape)):
        if part is None:
            continue
        ax = part if isinstance(part, tuple) else (part,)
        total = 1
        for a in ax:
            total *= sizes[a]
        assert dim % total == 0


@settings(deadline=None, max_examples=20)
@given(st.lists(st.floats(min_value=0.01, max_value=0.49), min_size=1, max_size=20))
def test_potential_recursion_monotone(gammas):
    """The certificate recursion L += 1/2 log(1-4g^2) strictly decreases
    and exp(L) in (0, 1] — certificates are always meaningful."""
    L = 0.0
    for g in gammas:
        L_new = L + 0.5 * np.log1p(-4.0 * g * g)
        assert L_new < L
        L = L_new
    assert 0.0 < np.exp(L) <= 1.0


@settings(deadline=None, max_examples=30)
@given(
    st.lists(
        st.floats(min_value=-100.0, max_value=-0.01, width=32),
        min_size=2,
        max_size=12,
    )
)
def test_eviction_never_evicts_delivery_argmin_uniform_delay(scores):
    """Worst-certificate-first eviction at capacity 1 under uniform
    delay: whatever gets evicted, every destination retains its delivery
    argmin — the best certificate among the other workers. This is the
    exactness lemma behind `inflight_capacity >= 1` being bit-identical
    to the dense oracle at uniform delay."""
    w = len(scores)
    score = jnp.asarray(scores, jnp.float32)
    q, _, _, _, _, _ = _queue_push(
        _empty_queue(w, 1),
        score,
        jnp.ones((w,), bool),
        jnp.arange(w),
        jnp.ones((w, w), jnp.int32),
        jnp.int32(0),
        8,
    )
    kept = np.asarray(q.cert[:, 0])
    sc = np.asarray(score)
    for dst in range(w):
        assert kept[dst] == min(sc[src] for src in range(w) if src != dst)


@settings(deadline=None, max_examples=5)
@given(
    st.lists(st.integers(min_value=1, max_value=5), min_size=8, max_size=8),
    st.sampled_from([0.0, 0.003, 0.01]),
    st.integers(min_value=1, max_value=3),
)
def test_sparse_control_certs_match_dense_uniform_delay(periods, eps, k):
    """control_plane="sparse" ships only top-k candidate triples, yet
    under uniform delay the protocol outcome (certificates, history)
    must equal dense control for ANY improvement schedule, eps, and k —
    the suppressed-runner-up argument in docs/architecture.md, probed
    here over random schedules instead of the fixed fixtures in
    tests/test_sparse_inflight.py."""
    w = len(periods)
    worker = ShardableToyWorker(periods, [0.01 * (i % 7 + 1) for i in range(w)])
    runs = {}
    for plane in ("dense", "sparse"):
        runs[plane] = make_engine(
            worker,
            EngineConfig(
                n_workers=w,
                max_rounds=24,
                eps=eps,
                gossip_top_k=k,
                control_plane=plane,
                seed=0,
                fault_spec="",  # oracle comparison: chaos CI leg must not steer it
            ),
        ).run()
    assert runs["sparse"].final_certificates == runs["dense"].final_certificates
    assert runs["sparse"].history == runs["dense"].history


@settings(deadline=None, max_examples=5)
@given(
    st.lists(st.integers(min_value=1, max_value=5), min_size=8, max_size=8),
    st.floats(min_value=0.0, max_value=0.4, width=32),
    st.floats(min_value=0.0, max_value=0.4, width=32),
    st.floats(min_value=0.0, max_value=0.4, width=32),
    st.integers(min_value=0, max_value=2),
    st.integers(min_value=0, max_value=10_000),
)
def test_cert_monotone_under_any_fault_schedule(periods, drop, dup, corrupt, reorder, seed):
    """Certificate monotonicity is an ACCEPT-gated invariant: faults can
    only remove, duplicate, delay, or corrupt in-flight copies, and the
    eps-gate + soundness check stand between the queue and local state —
    so per-worker certificates never increase and never go non-finite
    under ANY drop/duplicate/reorder/corruption schedule."""
    from repro.core.engine import FaultPlan

    w = len(periods)
    worker = ShardableToyWorker(periods, [0.01 * (i % 7 + 1) for i in range(w)])
    res = make_engine(
        worker,
        EngineConfig(
            n_workers=w,
            max_rounds=16,
            inflight_capacity=16,
            fault_plan=FaultPlan(
                drop_prob=drop,
                duplicate_prob=dup,
                corrupt_prob=corrupt,
                reorder_max=reorder,
                seed=seed,
            ),
            seed=0,
            fault_spec="",
        ),
    ).run()
    assert res.rounds == 16
    last = {}
    for _, wid, cert in res.history:
        assert np.isfinite(cert)
        assert cert <= last.get(wid, np.inf)
        last[wid] = cert
    assert all(np.isfinite(res.final_certificates))


@settings(deadline=None, max_examples=5)
@given(
    st.lists(st.integers(min_value=1, max_value=5), min_size=8, max_size=8),
    st.floats(min_value=0.05, max_value=0.6, width=32),
    st.integers(min_value=0, max_value=10_000),
)
def test_soundness_gate_never_suppresses_legitimate_improvement(periods, dup, seed):
    """An active FaultPlan runs EVERY in-flight certificate through the
    eps-gate soundness check, not just corrupted ones — so a
    duplication-only schedule is the adversarial probe that the gate
    only ever rejects messages that could never be accepted: for any
    random schedule the run must stay bit-identical to the clean run.
    (Monotone destination certificates make a non-improving arrival
    forever unacceptable; rejecting it at push time is invisible.)"""
    from repro.core.engine import FaultPlan

    w = len(periods)
    worker = ShardableToyWorker(periods, [0.01 * (i % 7 + 1) for i in range(w)])

    def run(plan):
        return make_engine(
            worker,
            EngineConfig(
                n_workers=w,
                max_rounds=16,
                inflight_capacity=16,
                fault_plan=plan,
                seed=0,
                fault_spec="",
            ),
        ).run()

    clean = run(None)
    faulted = run(FaultPlan(duplicate_prob=dup, seed=seed))
    assert faulted.final_certificates == clean.final_certificates
    assert faulted.history == clean.history
    assert faulted.messages_evicted == 0


@settings(deadline=None, max_examples=20)
@given(st.integers(min_value=1, max_value=6), st.integers(min_value=0, max_value=120))
def test_ring_slot_positions(S_pow, pos):
    """Ring-cache slot->absolute-position math: each slot holds the
    largest p <= pos with p % S == slot; all held positions are within
    the last S steps."""
    S = 2 ** S_pow
    slot = np.arange(S)
    kpos = pos - (pos - slot) % S
    assert (kpos <= pos).all()
    assert (kpos > pos - S).all()
    assert (kpos % S == slot).all()
