"""Docs are part of tier-1: a broken link, a drifted config reference,
or a quickstart command that no longer works fails the fast tier on
every push (the CI `docs` job additionally runs the link checker
standalone, without an install step).

Three guards:

  * every internal markdown link/anchor in README.md, docs/, ROADMAP.md
    and CHANGES.md resolves (tools/check_md_links.py);
  * docs/config.md cannot drift from EngineConfig or TMSNSGDConfig:
    every dataclass field and every REPRO_* env override must be
    documented, and every documented override must still exist in the
    code;
  * the README quickstart commands reference real files, and its tier-1
    verify line actually collects the suite (smoke-run with
    --collect-only: cheap, and zero collection errors is a standing
    ROADMAP requirement).
"""

from __future__ import annotations

import dataclasses
import os
import re
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import check_md_links  # noqa: E402

from repro.core.engine import EngineConfig  # noqa: E402

DOC_SURFACE = ["README.md", "docs", "ROADMAP.md", "CHANGES.md"]


def _fenced_blocks(md: str) -> list[str]:
    """Contents of ``` fenced code blocks, any language tag."""
    return re.findall(r"```[a-z]*\n(.*?)```", md, flags=re.DOTALL)


# ---------------------------------------------------------------------------
# link integrity
# ---------------------------------------------------------------------------


class TestLinks:
    def test_all_internal_links_resolve(self):
        files = check_md_links.collect_md(DOC_SURFACE, REPO)
        assert files, "doc surface is empty — README/docs went missing?"
        errors = []
        for md in files:
            errs, _, _ = check_md_links.check_file(md, REPO)
            errors.extend(errs)
        assert not errors, "broken markdown links:\n" + "\n".join(errors)

    def test_readme_and_docs_exist(self):
        for name in ("README.md", "docs/architecture.md", "docs/config.md"):
            assert (REPO / name).is_file(), f"{name} missing"


# ---------------------------------------------------------------------------
# docs/config.md <-> EngineConfig drift
# ---------------------------------------------------------------------------


class TestConfigReference:
    def _doc(self) -> str:
        return (REPO / "docs" / "config.md").read_text()

    def test_every_engine_config_field_documented(self):
        doc = self._doc()
        missing = [
            f.name
            for f in dataclasses.fields(EngineConfig)
            if f"`{f.name}`" not in doc
        ]
        assert not missing, (
            f"EngineConfig fields undocumented in docs/config.md: {missing}"
        )

    def _env_vars_in_code(self) -> set[str]:
        src = (REPO / "src" / "repro" / "core" / "engine.py").read_text()
        # only variables the code actually READS (not prose mentions)
        return set(re.findall(r"_env_(?:int|str|float)\(\"(REPRO_[A-Z_]+)\"", src))

    def test_every_env_override_documented(self):
        doc = self._doc()
        in_code = self._env_vars_in_code()
        assert in_code, "no REPRO_* overrides found in engine.py — parser moved?"
        missing = sorted(v for v in in_code if f"`{v}`" not in doc)
        assert not missing, f"env overrides undocumented in docs/config.md: {missing}"

    def test_no_phantom_env_overrides_documented(self):
        doc = self._doc()
        documented = set(re.findall(r"`(REPRO_[A-Z_]+)`", doc))
        phantom = sorted(documented - self._env_vars_in_code())
        assert not phantom, (
            f"docs/config.md documents env overrides the code no longer reads: {phantom}"
        )

    def test_every_sgd_config_field_documented(self):
        """The SGD-worker knobs (local_steps, ema, width_coef, ...)
        have their own reference section; it must track TMSNSGDConfig
        field-for-field like the EngineConfig table does."""
        from repro.core.tmsn_sgd import TMSNSGDConfig

        doc = self._doc()
        missing = [
            f.name
            for f in dataclasses.fields(TMSNSGDConfig)
            if f"`{f.name}`" not in doc
        ]
        assert not missing, (
            f"TMSNSGDConfig fields undocumented in docs/config.md: {missing}"
        )

    def test_ci_matrix_legs_match_workflow(self):
        """The legs table in docs/config.md names each matrix entry of
        the fast-multidevice job."""
        doc = self._doc()
        wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
        for leg in re.findall(r"- name: ([\w-]+)\n\s+gossip_mode", wf):
            assert f"`{leg}`" in doc, f"CI matrix leg {leg!r} missing from docs/config.md"

    def test_control_plane_documented_and_wired_into_ci(self):
        """The control-plane knob row must name both values, and the CI
        matrix must actually steer it — a renamed env var or a dropped
        matrix key fails here, not in a nightly surprise."""
        doc = self._doc()
        row = next(
            (ln for ln in doc.splitlines() if ln.strip().startswith("| `control_plane`")),
            None,
        )
        assert row is not None, "docs/config.md lost the `control_plane` knob row"
        assert "dense" in row and "sparse" in row
        wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
        assert "REPRO_CONTROL_PLANE" in wf, (
            "ci.yml no longer sets REPRO_CONTROL_PLANE — the sparse-control "
            "matrix leg is not steering the engines"
        )
        assert "control_plane: sparse" in wf, (
            "ci.yml lost the sparse-control matrix leg"
        )

    def test_fault_plan_documented_and_wired_into_ci(self):
        """The fault-injection knobs must be documented, and the chaos
        matrix leg must actually inject a plan — a dropped env wire or
        a neutered (all-zero) leg spec fails here."""
        doc = self._doc()
        for knob in ("fault_spec", "fault_plan", "spare_slots", "membership"):
            row = next(
                (ln for ln in doc.splitlines() if ln.strip().startswith(f"| `{knob}`")),
                None,
            )
            assert row is not None, f"docs/config.md lost the `{knob}` knob row"
        wf = (REPO / ".github" / "workflows" / "ci.yml").read_text()
        assert "REPRO_FAULT_PLAN" in wf, (
            "ci.yml no longer sets REPRO_FAULT_PLAN — the chaos matrix leg "
            "is not injecting faults into the engines"
        )
        m = re.search(r'fault_plan: "([^"]*drop=\d+[^"]*)"', wf)
        assert m is not None, (
            "ci.yml's chaos leg no longer carries an active fault plan "
            "(expected a fault_plan spec with a nonzero drop rate)"
        )
        assert "seed=" in m.group(1), (
            "the chaos leg's fault plan must pin a seed — an unseeded plan "
            "would make the leg nondeterministic across runs"
        )


# ---------------------------------------------------------------------------
# README quickstart
# ---------------------------------------------------------------------------


class TestQuickstart:
    def _readme(self) -> str:
        return (REPO / "README.md").read_text()

    def _commands(self) -> list[str]:
        cmds = []
        for block in _fenced_blocks(self._readme()):
            for line in block.splitlines():
                line = line.strip()
                if line and not line.startswith("#"):
                    cmds.append(line)
        return cmds

    def test_referenced_paths_exist(self):
        """Every path-like token in a README code block must exist —
        renaming an example without touching the README fails here."""
        missing = []
        for cmd in self._commands():
            for tok in cmd.split():
                if re.fullmatch(r"(examples|tests|benchmarks|docs|tools|src)/[\w./-]+", tok):
                    if not (REPO / tok).exists():
                        missing.append(f"{tok!r} (from: {cmd})")
        assert not missing, "README references missing files:\n" + "\n".join(missing)

    def test_python_module_invocations_importable(self):
        """`python -m benchmarks.run`-style lines must name modules that
        actually exist as files (import cost is too high here)."""
        for cmd in self._commands():
            m = re.search(r"python -m ([\w.]+)", cmd)
            if not m or m.group(1) == "pytest":
                continue
            mod_path = Path(m.group(1).replace(".", os.sep))
            assert (REPO / mod_path).with_suffix(".py").is_file() or (
                REPO / mod_path / "__main__.py"
            ).is_file(), f"README invokes missing module: {cmd}"

    def test_verify_line_present_and_collects(self):
        """The README's tier-1 verify line, smoke-run: the suite must
        COLLECT cleanly under the exact command the README gives
        (``--collect-only`` keeps it cheap; zero collection errors is
        the standing tier-1 requirement from ROADMAP.md)."""
        verify = [c for c in self._commands() if "python -m pytest" in c]
        assert verify, "README lost its tier-1 verify command"
        cmd = verify[0]
        assert cmd.startswith("PYTHONPATH=src"), (
            f"verify line must set PYTHONPATH=src, got: {cmd}"
        )
        proc = subprocess.run(
            ["bash", "-c", f"cd {REPO} && {cmd} --collect-only >/dev/null"],
            capture_output=True,
            text=True,
            timeout=300,
        )
        assert proc.returncode == 0, (
            f"README verify line failed to collect:\n{cmd}\n{proc.stderr[-2000:]}"
        )
