"""Launch-layer tests: sharding rules, step functions on the host mesh,
TMSN-SGD round, optimizer, checkpoint, input specs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config, reduced
from repro.core.tmsn_sgd import TMSNSGDConfig, init_tmsn_state, make_tmsn_round, tmsn_batch_specs
from repro.data.tokens import TokenPipeline, synthetic_token_batch
from repro.launch.sharding import fit_spec, param_pspecs
from repro.launch.steps import (
    INPUT_SHAPES,
    batch_specs,
    decode_specs,
    make_serve_step,
    make_train_step,
    shape_applicable,
)
from repro.models import init_params, loss_fn
from repro.optim import AdamWConfig, apply_updates, init_opt_state, warmup_cosine


class TestShardingRules:
    def test_fit_spec_drops_nondivisible(self):
        sizes = {"data": 16, "model": 16}
        assert fit_spec(P("model", "data"), (50280, 2048), sizes) == P(None, "data")
        assert fit_spec(P("data", "model"), (4096, 11008), sizes) == P("data", "model")
        sizes = {"pod": 2, "data": 16, "model": 16}
        assert fit_spec(P(("pod", "data"), None), (32, 128), sizes) == P(("pod", "data"), None)
        assert fit_spec(P(("pod", "data"), None), (31, 128), sizes) == P(None, None)

    def test_param_pspecs_cover_all_archs(self):
        for arch in ("yi-9b", "deepseek-v3-671b", "mamba2-1.3b", "zamba2-1.2b", "whisper-large-v3"):
            cfg = get_config(arch)
            shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
            specs = param_pspecs(shapes, cfg)
            flat_shapes = jax.tree.leaves(shapes)
            flat_specs = jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P))
            assert len(flat_shapes) == len(flat_specs)
            for sh, sp in zip(flat_shapes, flat_specs):
                assert len(sp) <= len(sh.shape), (arch, sh.shape, sp)

    def test_serve_mode_drops_fsdp_for_2d(self):
        cfg = get_config("yi-9b")
        shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        train = param_pspecs(shapes, cfg, mode="train")
        serve = param_pspecs(shapes, cfg, mode="serve")
        t = jax.tree.leaves(train, is_leaf=lambda x: isinstance(x, P))
        s = jax.tree.leaves(serve, is_leaf=lambda x: isinstance(x, P))
        assert any("data" in tuple(x) for x in t)
        # 2D serve specs never use the fsdp axis
        assert all("data" not in tuple(x) for x in s)


class TestInputSpecs:
    def test_all_shapes_defined(self):
        assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}

    def test_batch_specs_shapes(self):
        cfg = get_config("yi-9b")
        b = batch_specs(cfg, "train_4k")
        assert b["tokens"].shape == (256, 4096)
        b = batch_specs(cfg, "prefill_32k")
        assert b["tokens"].shape == (32, 32768)

    def test_decode_specs_cache_rank(self):
        cfg = get_config("gemma3-12b")
        d = decode_specs(cfg, "decode_32k")
        assert d["token"].shape == (128, 1)
        leaves = jax.tree.leaves(d["caches"])
        assert all(x.shape[2] == 32768 for x in leaves if len(x.shape) == 5)

    def test_long_500k_applicability(self):
        assert shape_applicable(get_config("mamba2-1.3b"), "long_500k")[0]
        assert shape_applicable(get_config("gemma3-12b"), "long_500k")[0]
        assert shape_applicable(get_config("zamba2-1.2b"), "long_500k")[0]
        ok, why = shape_applicable(get_config("yi-9b"), "long_500k")
        assert not ok and "full-attention" in why

    def test_frontend_specs_present(self):
        cfg = get_config("whisper-large-v3")
        b = batch_specs(cfg, "train_4k")
        assert b["frontend_embeds"].shape == (256, 1500, 128)


class TestStepsOnHost:
    def test_train_step_runs_and_descends(self):
        cfg = reduced(get_config("starcoder2-7b"))
        opt_cfg = AdamWConfig(lr=1e-3)
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
        key = jax.random.PRNGKey(1)
        losses = []
        for i in range(8):
            batch = synthetic_token_batch(jax.random.fold_in(key, i), 4, 64, cfg.vocab)
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]  # learns the token marginals

    def test_serve_step_runs(self):
        from repro.models import init_cache

        cfg = reduced(get_config("internlm2-20b"))
        params = init_params(cfg, jax.random.PRNGKey(0))
        serve = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
        caches = init_cache(cfg, 2, 16)
        tok = jnp.zeros((2, 1), jnp.int32)
        for i in range(4):
            tok, caches = serve(params, tok, caches, jnp.asarray(i, jnp.int32))
        assert tok.shape == (2, 1)
        assert int(tok.max()) < cfg.vocab


@pytest.mark.slow
class TestTMSNSGD:
    def test_round_improves_and_certs_monotone(self):
        """Improvement is measured on a FIXED held-out batch, before vs
        after the run. The old assertion compared per-round training
        losses, each computed on a fresh random batch — batch-to-batch
        noise (~±0.05 at this scale) dwarfs the expected descent
        (~0.003 over 4 rounds), so the test failed or passed on seed
        luck, not on whether the round worked. The held-out descent is
        deterministic per seed and an order of magnitude larger than
        any cross-platform numeric jitter."""
        cfg = reduced(get_config("yi-9b"))
        opt_cfg = AdamWConfig(lr=1e-3)
        tcfg = TMSNSGDConfig(num_workers=2, local_steps=2, eps=0.0)
        params_w, opt_w, cert_w = init_tmsn_state(cfg, opt_cfg, tcfg, jax.random.PRNGKey(0))
        fn = jax.jit(make_tmsn_round(cfg, opt_cfg, tcfg), donate_argnums=(0, 1))
        key = jax.random.PRNGKey(1)
        eval_batch = synthetic_token_batch(jax.random.fold_in(key, 999), 8, 32, cfg.vocab)
        eval_fn = jax.jit(lambda p: loss_fn(p, cfg, eval_batch)[0])
        loss_before = float(eval_fn(jax.tree.map(lambda a: a[0], params_w)))
        certs_hist = []
        for r in range(4):
            batch = synthetic_token_batch(jax.random.fold_in(key, r), 2 * 2 * 2, 32, cfg.vocab)
            batch_w = {k: v.reshape((2, 2, 2) + v.shape[1:]) for k, v in batch.items()}
            params_w, opt_w, cert_w, loss = fn(params_w, opt_w, cert_w, batch_w)
            certs_hist.append(np.asarray(cert_w).copy())
        loss_after = float(eval_fn(jax.tree.map(lambda a: a[0], params_w)))
        assert loss_after < loss_before  # learns the token marginals
        for a, b in zip(certs_hist[1:], certs_hist[2:]):
            assert (b <= a + 1e-2).all()

    def test_adoption_copies_winner(self):
        """With a huge eps nothing is adopted; with eps=-inf everything
        adopts the winner -> all workers identical afterwards."""
        cfg = reduced(get_config("yi-9b"))
        opt_cfg = AdamWConfig(lr=1e-3)
        key = jax.random.PRNGKey(0)
        for eps, expect_same in ((1e9, False), (-1e9, True)):
            tcfg = TMSNSGDConfig(num_workers=2, local_steps=1, eps=eps)
            params_w, opt_w, cert_w = init_tmsn_state(cfg, opt_cfg, tcfg, key)
            fn = jax.jit(make_tmsn_round(cfg, opt_cfg, tcfg))
            batch = synthetic_token_batch(key, 2 * 1 * 2, 32, cfg.vocab)
            batch_w = {k: v.reshape((2, 1, 2) + v.shape[1:]) for k, v in batch.items()}
            params_w, opt_w, cert_w, _ = fn(params_w, opt_w, cert_w, batch_w)
            emb = np.asarray(params_w["embed"])
            same = bool(np.allclose(emb[0], emb[1]))
            assert same == expect_same

    def test_batch_specs(self):
        cfg = get_config("yi-9b")
        tcfg = TMSNSGDConfig(num_workers=16, local_steps=4)
        spec = tmsn_batch_specs(cfg, tcfg, 4096, 256)
        assert spec["tokens"].shape == (16, 4, 16, 4096)


class TestOptim:
    def test_adamw_moves_toward_minimum(self):
        cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
        params = {"w": jnp.asarray([5.0, -3.0])}
        state = init_opt_state(params, cfg)
        for _ in range(60):
            grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
            params, state = apply_updates(params, grads, state, cfg)
        assert float(jnp.abs(params["w"]).max()) < 1.0

    def test_bf16_state_dtype(self):
        cfg = AdamWConfig(state_dtype="bfloat16")
        params = {"w": jnp.ones((4,), jnp.float32)}
        state = init_opt_state(params, cfg)
        assert state["mu"]["w"].dtype == jnp.bfloat16

    def test_warmup_cosine(self):
        assert float(warmup_cosine(0, 1.0, 10, 100)) == 0.0
        assert float(warmup_cosine(10, 1.0, 10, 100)) == pytest.approx(1.0)
        assert float(warmup_cosine(100, 1.0, 10, 100)) == pytest.approx(0.1, abs=1e-5)


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        cfg = reduced(get_config("mamba2-1.3b"))
        params = init_params(cfg, jax.random.PRNGKey(0))
        path = str(tmp_path / "ckpt.npz")
        save_checkpoint(path, params)
        restored = load_checkpoint(path, params)
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_shape_mismatch_raises(self, tmp_path):
        path = str(tmp_path / "c.npz")
        save_checkpoint(path, {"w": jnp.ones((2,))})
        with pytest.raises(ValueError):
            load_checkpoint(path, {"w": jnp.ones((3,))})


class TestPipeline:
    def test_token_pipeline_deterministic(self):
        p1 = list(zip(range(2), TokenPipeline(batch=2, seq=8, vocab=100, seed=3)))
        p2 = list(zip(range(2), TokenPipeline(batch=2, seq=8, vocab=100, seed=3)))
        for (_, a), (_, b) in zip(p1, p2):
            np.testing.assert_array_equal(np.asarray(a["tokens"]), np.asarray(b["tokens"]))

    def test_element_spec_matches(self):
        p = TokenPipeline(batch=2, seq=8, vocab=100, frontend_len=4, frontend_dim=8)
        spec = p.element_spec()
        batch = next(iter(p))
        for k, v in spec.items():
            assert batch[k].shape == v.shape and batch[k].dtype == v.dtype
