"""Serving-tier tests: cache re-buffering bit-identity, torn-read-free
adoption, the no-publish path's bit-identity with the legacy serve
loop, the sampling knob, and the no-recompile-after-warmup pin.

The bit-identity tests are the load-bearing ones: the continuous
batcher replaced the legacy scalar-``pos`` serve loop wholesale, and
these pin that with no publisher attached the replacement is not
"close" but EXACTLY the old path, token for token — so every
production consumer of `serve()` sees an unchanged contract.
"""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.launch.serve import serve
from repro.launch.serving import (
    AdoptionSlot,
    ContinuousServer,
    Request,
    ServingConfig,
    rebuffer_caches,
)
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_cache, init_params
from repro.models.config import ArchConfig, layer_segments

#: tiny self-contained arch for the loop-mechanics tests (the zoo's
#: reduced() configs are reserved for the per-kind cache tests below)
_TINY = ArchConfig(
    name="test-serving",
    arch_type="llama",
    num_layers=2,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    d_ff=64,
    vocab=128,
    remat=False,
    compute_dtype="float32",
)


def _prompts(cfg, batch, prompt_len, seed=0):
    rng = np.random.default_rng(seed)
    return rng.integers(0, cfg.vocab, (batch, prompt_len)).astype(np.int32)


class TestRebufferCaches:
    """rebuffer_caches vs a transparent numpy reference: allocate the
    max_len buffers, write the prompt prefix with plain indexing, and
    require the result — and the decode steps that follow — to be
    bitwise identical. Covers an attention arch (self-attn K/V prefix
    write) and an SSM arch (full-state copy), per the cache-kind
    branches in rebuffer_caches."""

    def _reference(self, cfg, pre, batch, max_len, prompt_len, enc_len):
        full = init_cache(cfg, batch, max_len, enc_len=enc_len)
        out = []
        for (unit, reps), seg_full, seg_pre in zip(layer_segments(cfg), full, pre):
            seg_out = []
            for spec, buf_full, buf_pre in zip(unit, seg_full, seg_pre):
                entry = []
                for b_full, b_pre in zip(buf_full, buf_pre):
                    if b_full.shape == b_pre.shape:
                        # SSM state / conv tail / cross-attn: full copy
                        entry.append(np.asarray(b_pre).astype(b_full.dtype))
                    else:
                        # self-attn K/V: prompt prefix along seq axis 2
                        arr = np.asarray(b_full).copy()
                        arr[:, :, :prompt_len] = np.asarray(b_pre).astype(arr.dtype)
                        entry.append(arr)
                seg_out.append(tuple(entry))
            out.append(tuple(seg_out))
        return out

    @pytest.mark.parametrize("arch", ["yi-9b", "mamba2-1.3b"])
    def test_bit_identical_to_numpy_reference_and_decode(self, arch):
        cfg = reduced(get_config(arch))
        batch, prompt_len, max_len = 2, 8, 16
        params = init_params(cfg, jax.random.PRNGKey(0))
        prompts = jnp.asarray(_prompts(cfg, batch, prompt_len))
        b = {
            "tokens": prompts,
            "labels": prompts,
            "mask": jnp.ones_like(prompts, jnp.float32),
        }
        tok, pre = jax.jit(make_prefill_step(cfg))(params, b)
        got = rebuffer_caches(cfg, pre, batch, max_len, prompt_len, 0)
        want = self._reference(cfg, pre, batch, max_len, prompt_len, 0)
        for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        # and the decode trajectories from the two caches are identical
        step = jax.jit(make_serve_step(cfg))
        want_c = jax.tree.map(jnp.asarray, want)
        tok_g, tok_w = tok, tok
        for i in range(4):
            tok_g, got = step(params, tok_g, got, jnp.asarray(prompt_len + i, jnp.int32))
            tok_w, want_c = step(params, tok_w, want_c, jnp.asarray(prompt_len + i, jnp.int32))
            np.testing.assert_array_equal(np.asarray(tok_g), np.asarray(tok_w))


class TestAdoptionSlot:
    def test_empty_slot(self):
        slot = AdoptionSlot()
        assert slot.version == 0
        assert slot.acquire() is None
        assert np.isnan(slot.latest_cert)

    def test_publish_versions_monotone(self):
        slot = AdoptionSlot()
        assert slot.publish({"w": 1}, cert=2.0, round=3) == 1
        assert slot.publish({"w": 2}, cert=1.0, round=4) == 2
        snap = slot.acquire()
        assert snap.version == 2 and snap.params == {"w": 2}
        assert snap.cert == 1.0 and snap.round == 4
        assert slot.latest_cert == 1.0
        assert slot.publishes == 2

    def test_no_torn_reads_under_concurrent_publishes(self):
        """Hammer test for the write-then-flip protocol: the writer
        publishes sentinel snapshots whose every field encodes the
        version; readers must only ever see internally-consistent
        (version, params, cert) triples — a torn read would pair one
        version's params with another's cert or version."""
        slot = AdoptionSlot()
        n_pub = 4000
        errors: list[str] = []
        stop = threading.Event()

        def writer():
            for v in range(1, n_pub + 1):
                slot.publish({"w": np.full(8, v, np.int64)}, cert=-float(v), round=v)
            stop.set()

        def reader():
            seen_any = False
            while not stop.is_set() or not seen_any:
                snap = slot.acquire()
                if snap is None:
                    continue
                seen_any = True
                w = snap.params["w"]
                if not (w == snap.version).all():
                    errors.append(f"params {w[0]} != version {snap.version}")
                if snap.cert != -float(snap.version) or snap.round != snap.version:
                    errors.append(f"cert/round torn at v{snap.version}")

        threads = [threading.Thread(target=writer)] + [
            threading.Thread(target=reader) for _ in range(3)
        ]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert not errors, errors[:5]
        assert slot.version == n_pub


class TestServeBitIdentity:
    """With no publisher, the rebuilt serve() must generate EXACTLY the
    tokens of the pre-refactor loop (batched prefill + rebuffer +
    scalar-``pos`` make_serve_step), reimplemented inline here as the
    reference."""

    def _legacy_generate(self, cfg, batch, prompt_len, gen, seed=0):
        key = jax.random.PRNGKey(seed)
        params = init_params(cfg, key)
        prompts = jax.random.randint(
            jax.random.fold_in(key, 1), (batch, prompt_len), 0, cfg.vocab, jnp.int32
        )
        b = {
            "tokens": prompts,
            "labels": prompts,
            "mask": jnp.ones_like(prompts, jnp.float32),
        }
        if cfg.frontend:
            b["frontend_embeds"] = (
                jax.random.normal(
                    jax.random.fold_in(key, 2),
                    (batch, cfg.frontend_len, cfg.frontend_dim),
                )
                * 0.02
            )
        prefill_fn = jax.jit(make_prefill_step(cfg))
        serve_fn = jax.jit(make_serve_step(cfg), donate_argnums=(2,))
        tok, pre = prefill_fn(params, b)
        enc_len = cfg.frontend_len if cfg.is_encdec() else 0
        caches = rebuffer_caches(cfg, pre, batch, prompt_len + gen, prompt_len, enc_len)
        toks = [np.asarray(tok)]
        for i in range(gen - 1):
            tok, caches = serve_fn(params, tok, caches, jnp.asarray(prompt_len + i, jnp.int32))
            toks.append(np.asarray(tok))
        return np.concatenate(toks, axis=1)

    @pytest.mark.parametrize("arch", ["yi-9b", "mamba2-1.3b"])
    def test_no_publish_serve_matches_legacy(self, arch):
        cfg = reduced(get_config(arch))
        batch, prompt_len, gen = 2, 8, 6
        want = self._legacy_generate(cfg, batch, prompt_len, gen)
        out = serve(cfg, batch, prompt_len, gen)
        np.testing.assert_array_equal(out["generated"], want)
        assert out["adoptions"] == 0
        assert out["metrics"]["dropped_requests"] == 0


class TestSamplingKnob:
    """The previously-dead ``greedy`` parameter now changes behavior."""

    def test_sampling_differs_from_greedy_and_is_seeded(self):
        a = serve(_TINY, 2, 8, 8, greedy=True)
        b = serve(_TINY, 2, 8, 8, greedy=False, temperature=4.0)
        c = serve(_TINY, 2, 8, 8, greedy=False, temperature=4.0)
        assert not np.array_equal(a["generated"], b["generated"])
        np.testing.assert_array_equal(b["generated"], c["generated"])

    def test_nonpositive_temperature_rejected(self):
        with pytest.raises(ValueError, match="temperature"):
            ServingConfig(slots=1, prompt_len=4, max_new=4, greedy=False, temperature=0.0)


class TestContinuousServer:
    def _server(self, slots=2, max_new=6, **kw):
        scfg = ServingConfig(slots=slots, prompt_len=8, max_new=max_new, seed=0, **kw)
        return ContinuousServer(_TINY, scfg, init_params(_TINY, jax.random.PRNGKey(0)))

    def _reqs(self, n, max_new=6, seed=0):
        p = _prompts(_TINY, n, 8, seed)
        return [Request(rid=i, prompt=p[i], max_new=max_new) for i in range(n)]

    def test_request_validation(self):
        server = self._server()
        with pytest.raises(ValueError, match="max_new"):
            server.run([Request(rid=0, prompt=np.zeros(8, np.int32), max_new=99)])
        with pytest.raises(ValueError, match="prompt"):
            server.run([Request(rid=0, prompt=np.zeros(5, np.int32), max_new=2)])

    def test_no_recompiles_after_warmup(self):
        """The compile-count pin: continuous admission (7 staggered
        requests over 2 slots) plus mid-run adoption triggers ZERO new
        traces after warmup()."""
        server = self._server()
        server.warmup()
        counts = server.compile_counts()
        slot = AdoptionSlot()
        slot.publish(init_params(_TINY, jax.random.PRNGKey(1)), cert=0.5)
        reqs = [
            Request(rid=i, prompt=p, max_new=2 + (i % 5))
            for i, p in enumerate(_prompts(_TINY, 7, 8))
        ]
        results, m = server.run(reqs, slot=slot)
        assert m["recompiles"] == 0
        assert server.compile_counts() == counts
        assert m["dropped_requests"] == 0 and len(results) == 7

    def test_adoption_mid_stream(self):
        """Two snapshots published mid-run are both adopted; requests
        spanning an adoption record multiple versions; nothing drops."""
        server = self._server(slots=2, max_new=10)
        server.warmup()
        slot = AdoptionSlot()
        snaps = {
            2: (init_params(_TINY, jax.random.PRNGKey(1)), 1.0),
            5: (init_params(_TINY, jax.random.PRNGKey(2)), 0.5),
        }

        def hook(srv, step):
            if step in snaps:
                params, cert = snaps[step]
                slot.publish(params, cert=cert)

        results, m = server.run(self._reqs(4, max_new=10), slot=slot, step_hook=hook)
        assert m["adoptions"] == 2
        assert m["dropped_requests"] == 0
        assert m["recompiles"] == 0
        assert server.adopted_version == 2
        assert server.served_cert == 0.5
        # the first wave started on the constructor params (version 0)
        # and finished under both published snapshots
        assert any(r.versions == (0, 1, 2) for r in results)
        # tokens change when the model changes: the post-adoption run
        # differs from a run that never adopts
        server2 = self._server(slots=2, max_new=10)
        server2.warmup()
        static, _ = server2.run(self._reqs(4, max_new=10))
        changed = any(
            not np.array_equal(a.tokens, b.tokens) for a, b in zip(results, static)
        )
        assert changed

    def test_max_new_one_retires_at_prefill(self):
        server = self._server()
        results, m = server.run(self._reqs(3, max_new=1))
        assert m["dropped_requests"] == 0
        assert all(len(r.tokens) == 1 for r in results)

    def test_results_sorted_and_complete(self):
        server = self._server(slots=2)
        results, m = server.run(self._reqs(5, max_new=3))
        assert [r.rid for r in results] == list(range(5))
        assert all(len(r.tokens) == 3 for r in results)
        assert m["requests_completed"] == 5
