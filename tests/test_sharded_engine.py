"""Sharded/unsharded engine equivalence.

The single-device ``TMSNEngine`` is pinned against the event-driven
fidelity-1 oracle in ``tests/test_engine.py``; these tests close the
chain by pinning the shard-mapped engine against the single-device one:
on identical configs and seeds the final certificates must be
IDENTICAL — including fail-stop masks, laggard compute credit, and
per-link round delays — so sharding is a pure execution-substrate
choice with no protocol semantics of its own.

Needs >= 2 devices; CI's ``fast-multidevice`` leg forces 8 host devices
via ``XLA_FLAGS=--xla_force_host_platform_device_count=8``. On a
single-device run the whole module skips.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.boosting import BatchedSparrowWorker, SparrowConfig
from repro.boosting.scanner import ScannerConfig
from repro.core.engine import EngineConfig, TMSNEngine, make_engine, quantize_latency
from repro.core.engine_sharded import ShardedTMSNEngine, sharded_engine_available
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split
from repro.launch.mesh import make_worker_mesh

pytestmark = pytest.mark.skipif(
    not sharded_engine_available(),
    reason="sharded engine needs >=2 devices "
    "(CI forces 8 via --xla_force_host_platform_device_count)",
)


def _mesh_for(w: int):
    """Largest worker mesh the visible devices support for W workers."""
    n = len(jax.devices())
    while w % n:
        n -= 1
    return make_worker_mesh(n)


# ---------------------------------------------------------------------------
# Toy worker, sharding-contract compliant: every per-worker constant
# (period, dec, global worker id) lives IN the state so it shards along
# with the worker axis — the contract the engine_sharded docstring
# spells out (test_engine.py's toy closes over (W,) arrays instead,
# which is exactly what breaks under shard_map).
# ---------------------------------------------------------------------------


class ShardableToyWorker:
    def __init__(self, period, dec):
        self._period = jnp.asarray(period, jnp.int32)
        self._dec = jnp.asarray(dec, jnp.float32)

    def init_batch(self, n_workers, seed):
        z = jnp.zeros((n_workers,), jnp.int32)
        return {
            "segs": z,
            "fires": z,
            "cert": jnp.zeros((n_workers,), jnp.float32),
            "from": jnp.full((n_workers,), -1, jnp.int32),
            "owner": jnp.arange(n_workers, dtype=jnp.int32),
            "period": self._period,
            "dec": self._dec,
        }

    def scan_round(self, state, mask):
        segs = state["segs"] + mask.astype(jnp.int32)
        fired = mask & (segs % state["period"] == 0)
        fires = state["fires"] + fired.astype(jnp.int32)
        own = -state["dec"] * fires
        cert = jnp.where(fired, jnp.minimum(state["cert"], own), state["cert"])
        new = dict(state, segs=segs, fires=fires, cert=cert)
        return new, mask.astype(jnp.float32), fired

    def needs_resample(self, state):
        return jnp.zeros(state["cert"].shape, bool)

    def resample_round(self, state, do):
        return state, jnp.zeros(state["cert"].shape, jnp.float32)

    def certificates(self, state):
        return state["cert"]

    def export_models(self, state):
        return {"owner": state["owner"], "cert": state["cert"], "adopted_from": state["from"]}

    def adopt_batch(self, state, models, certs, take):
        new = dict(state)
        new["cert"] = jnp.where(take, certs, state["cert"])
        new["from"] = jnp.where(take, models["owner"], state["from"])
        return new, jnp.zeros(state["cert"].shape, jnp.float32)

    def payload_bytes(self):
        return 8


def _run_pair(period, dec, **cfg):
    """(single-device result, sharded result) on identical configs."""
    w = len(period)
    cfg.setdefault("fault_spec", "")  # identity pair: no CI chaos-leg injection
    res1 = TMSNEngine(ShardableToyWorker(period, dec), EngineConfig(n_workers=w, **cfg)).run()
    eng = make_engine(
        ShardableToyWorker(period, dec),
        EngineConfig(n_workers=w, mesh=_mesh_for(w), **cfg),
    )
    assert isinstance(eng, ShardedTMSNEngine)
    return res1, eng.run()


class TestToyEquivalence:
    def test_single_sender_identical(self):
        w = 16
        res1, res8 = _run_pair(
            [1] + [10**9] * (w - 1),
            [0.1] * w,
            delay_rounds=1,
            target_certificate=-0.95,
            max_rounds=500,
        )
        assert res8.final_certificates == res1.final_certificates
        assert res8.rounds == res1.rounds
        # traffic counters are per-shard partials; the reduced totals
        # must match the single-device scalars exactly
        assert res8.messages_sent == res1.messages_sent
        assert res8.messages_accepted == res1.messages_accepted
        assert res8.messages_discarded == res1.messages_discarded
        # ring routing across shards: every adopter took worker 0's model
        assert all(int(m["adopted_from"]) == 0 for m in res8.final_models[1:])

    def test_fail_stop_mask_identical(self):
        w = 8
        fail = [5] + [10**6] * (w - 1)
        res1, res8 = _run_pair(
            [1] + [10**9] * (w - 1), [0.1] * w, fail_round=fail, max_rounds=30
        )
        assert res8.final_certificates == res1.final_certificates
        assert res8.rounds == res1.rounds == 30  # no stall after the death

    def test_laggard_credit_identical(self):
        w = 8
        speed = [1.0] * (w - 2) + [0.25, 0.5]
        res1, res8 = _run_pair([1] * w, [0.1] * w, speed=speed, max_rounds=40)
        assert res8.final_certificates == res1.final_certificates
        assert res8.sim_time == res1.sim_time

    def test_link_delay_matrix_identical(self):
        w = 8
        delays = quantize_latency(0.05, 0.02, 0.05, w, seed=1)
        # pinned dense (both planes): under heterogeneous delays gated
        # gossip AND the sparse control plane are explicit
        # approximations, and this test asserts strict equality
        res1, res8 = _run_pair(
            [1, 2] * (w // 2), [0.05 * (i + 1) for i in range(w)],
            delay_rounds=delays, max_rounds=25, gossip_mode="dense",
            control_plane="dense",
        )
        assert res8.final_certificates == res1.final_certificates
        assert res8.messages_sent == res1.messages_sent
        assert res8.messages_discarded == res1.messages_discarded

    def test_gossip_bytes_reported(self):
        # pinned dense (both planes): the CI matrix also runs the tier
        # with REPRO_GOSSIP_MODE=gated / REPRO_CONTROL_PLANE=sparse,
        # either of which would change the footprint
        _, res8 = _run_pair(
            [1] * 8, [0.1] * 8, max_rounds=5, gossip_mode="dense",
            control_plane="dense",
        )
        # all_gather of payload (8B) + f32 cert + fired flag, per worker
        assert res8.gossip_bytes_per_round == 8 * (8 + 4 + 1)
        assert res8.gossip_mode == "dense"


# ---------------------------------------------------------------------------
# Gated gossip: payloads move only for each device's top-k improved
# candidates. Under UNIFORM delay the delivery argmin is always among
# the per-shard minima, so gated must equal dense exactly; the configs
# below use more workers than devices so gating is non-vacuous (with
# W_local = 1 every improver is trivially its shard's top-1).
# ---------------------------------------------------------------------------


def _run_modes(period, dec, **cfg):
    """(dense result, gated result) through the sharded engine."""
    w = len(period)
    cfg.setdefault("fault_spec", "")  # cross-mode identity: no chaos-leg injection
    out = []
    for mode in ("dense", "gated"):
        eng = make_engine(
            ShardableToyWorker(period, dec),
            EngineConfig(n_workers=w, mesh=_mesh_for(w), gossip_mode=mode, **cfg),
        )
        out.append(eng.run())
    return out


class TestGatedGossip:
    W = 32  # ≥ 4 workers per shard on ≤ 8 devices

    def _workload(self):
        w = self.W
        # every worker fires (period 1 or 2) with distinct decrements:
        # several simultaneous improvers per shard every round
        return [1, 2] * (w // 2), [0.01 * (i + 1) for i in range(w)]

    def test_gated_equals_dense_uniform_delay(self):
        period, dec = self._workload()
        # pinned dense control: under sparse control both gossip modes
        # push only candidate triples, so the strict traffic inequality
        # below would collapse to equality
        resd, resg = _run_modes(period, dec, max_rounds=30, control_plane="dense")
        assert resg.final_certificates == resd.final_certificates
        assert resg.history == resd.history
        # the gate is what shrinks traffic: strictly fewer pushes (on a
        # machine with >= W devices gating is vacuous and counts tie)
        if _mesh_for(self.W).shape["workers"] < self.W:
            assert 0 < resg.messages_sent < resd.messages_sent

    def test_gated_fail_stop_and_laggard_identical(self):
        period, dec = self._workload()
        w = self.W
        speed = [1.0] * (w - 2) + [0.25, 0.5]
        fail = [5] + [10**6] * (w - 1)
        resd, resg = _run_modes(
            period, dec, speed=speed, fail_round=fail, max_rounds=25
        )
        assert resg.final_certificates == resd.final_certificates
        assert resg.history == resd.history
        assert resg.rounds == resd.rounds == 25

    def test_gated_with_chunked_dispatch_identical(self):
        """Both new hot-path reworks at once: gated gossip inside a
        chunked scan still equals the dense unchunked run."""
        period, dec = self._workload()
        w = self.W
        runs = {}
        for mode, rpd in (("dense", 1), ("gated", 8)):
            runs[mode] = make_engine(
                ShardableToyWorker(period, dec),
                EngineConfig(n_workers=w, mesh=_mesh_for(w), gossip_mode=mode,
                             rounds_per_dispatch=rpd, max_rounds=24),
            ).run()
        assert runs["gated"].final_certificates == runs["dense"].final_certificates
        assert runs["gated"].history == runs["dense"].history

    def test_gated_bytes_accounting(self):
        period, dec = self._workload()
        # pinned dense control: these are the dense-control-plane byte
        # formulas (sparse control has its own accounting test in
        # tests/test_sparse_inflight.py)
        resd, resg = _run_modes(period, dec, max_rounds=5, control_plane="dense")
        w = self.W
        n_dev = _mesh_for(w).shape["workers"]
        p = 8  # toy payload
        assert resd.gossip_bytes_per_round == w * (p + 4 + 1)
        assert resg.gossip_bytes_per_round == w * 5 + n_dev * 1 * (p + 4)
        assert resg.gossip_mode == "gated" and resd.gossip_mode == "dense"

    def test_top_k_widens_payload_leg(self):
        period, dec = self._workload()
        w = self.W
        n_dev = _mesh_for(w).shape["workers"]
        # pinned dense control throughout: the byte formula and the
        # strict messages_sent equality below are dense-control facts
        eng = make_engine(
            ShardableToyWorker(period, dec),
            EngineConfig(n_workers=w, mesh=_mesh_for(w), gossip_mode="gated",
                         gossip_top_k=3, max_rounds=10, control_plane="dense"),
        )
        res = eng.run()
        assert res.gossip_bytes_per_round == w * 5 + n_dev * 3 * (8 + 4)
        # k = W_local candidates per shard degenerates to dense
        # semantics (every improver ships), certs must still match
        resd = make_engine(
            ShardableToyWorker(period, dec),
            EngineConfig(n_workers=w, mesh=_mesh_for(w), gossip_mode="dense",
                         max_rounds=10, control_plane="dense"),
        ).run()
        full = make_engine(
            ShardableToyWorker(period, dec),
            EngineConfig(n_workers=w, mesh=_mesh_for(w), gossip_mode="gated",
                         gossip_top_k=w, max_rounds=10, control_plane="dense"),
        ).run()
        assert full.final_certificates == resd.final_certificates
        assert full.messages_sent == resd.messages_sent

    def test_rejects_bad_mode(self):
        toy = ShardableToyWorker([1] * 8, [0.1] * 8)
        with pytest.raises(ValueError, match="gossip_mode"):
            make_engine(
                toy,
                EngineConfig(n_workers=8, mesh=_mesh_for(8), gossip_mode="sparse"),
            )


class TestChunkedSharded:
    def test_chunked_equals_unchunked_with_target(self):
        w = 16
        runs = {}
        for rpd in (1, 8):
            eng = make_engine(
                ShardableToyWorker([1] + [10**9] * (w - 1), [0.1] * w),
                EngineConfig(n_workers=w, mesh=_mesh_for(w), rounds_per_dispatch=rpd,
                             target_certificate=-0.95, max_rounds=500),
            )
            runs[rpd] = eng.run()
        assert runs[8].rounds == runs[1].rounds == 10
        assert runs[8].final_certificates == runs[1].final_certificates
        assert runs[8].history == runs[1].history
        assert runs[8].messages_sent == runs[1].messages_sent

    def test_chunked_heterogeneous_identical(self):
        w = 16
        speed = [1.0] * (w - 2) + [0.25, 0.5]
        fail = [10**6] * (w - 1) + [5]
        runs = {}
        for rpd in (1, 8):
            eng = make_engine(
                ShardableToyWorker([1] * w, [0.05 * (i + 1) for i in range(w)]),
                EngineConfig(n_workers=w, mesh=_mesh_for(w), rounds_per_dispatch=rpd,
                             speed=speed, fail_round=fail, max_rounds=21),
            )
            runs[rpd] = eng.run()
        assert runs[8].final_certificates == runs[1].final_certificates
        assert runs[8].history == runs[1].history
        assert runs[8].rounds == runs[1].rounds == 21


# ---------------------------------------------------------------------------
# Hierarchical (pod, workers) mesh: intra-pod gossip stays the per-round
# all_gather over the `workers` axis; cross-pod exchange accumulates
# improvements in a pending tier and ships only each device's top-k
# freshest certificates over the `pod` axis every cross_pod_every_k
# rounds. At cross_pod_every_k=1 under uniform delay the pod engine must
# be bit-identical to the FLAT all-device engine (final certs, history,
# adoptions) — the same monotonicity argument as gated==dense. At k>1 it
# is an explicit, benchmark-measured approximation.
# ---------------------------------------------------------------------------


def _pod_mesh_or_skip(pods: int = 2):
    n = len(jax.devices())
    if n < 2 * pods or n % pods:
        pytest.skip(f"pod mesh needs >= {2 * pods} devices divisible into {pods} pods")
    return make_worker_mesh(n, pods=pods)


def _run_pod_pair(period, dec, pods=2, **cfg):
    """(flat all-device result, pod-mesh result) on identical configs.

    Identity tests must pin cross_pod_every_k/top_k explicitly (the CI
    pod matrix leg overrides the env defaults to an approximating k)."""
    w = len(period)
    cfg.setdefault("fault_spec", "")  # identity pair: no CI chaos-leg injection
    pod_mesh = _pod_mesh_or_skip(pods)
    flat = make_engine(
        ShardableToyWorker(period, dec),
        EngineConfig(n_workers=w, mesh=_mesh_for(w), **cfg),
    ).run()
    eng = make_engine(
        ShardableToyWorker(period, dec),
        EngineConfig(n_workers=w, mesh=pod_mesh, **cfg),
    )
    assert isinstance(eng, ShardedTMSNEngine)
    return flat, eng.run()


class TestPodMesh:
    W = 32

    def _workload(self):
        w = self.W
        # several simultaneous improvers per device every round, so both
        # the gated intra tier and the cross-pod top-k tier are
        # non-vacuous
        return [1, 2] * (w // 2), [0.01 * (i + 1) for i in range(w)]

    def test_k1_identical_to_flat_dense(self):
        period, dec = self._workload()
        flat, pod = _run_pod_pair(
            period, dec, max_rounds=30, gossip_mode="dense",
            cross_pod_every_k=1, cross_pod_top_k=1,
        )
        assert pod.final_certificates == flat.final_certificates
        assert pod.history == flat.history
        assert pod.messages_accepted == flat.messages_accepted
        # the DCN tier actually carried traffic
        assert 0 < pod.messages_sent_dcn < pod.messages_sent

    def test_k1_identical_to_flat_gated(self):
        period, dec = self._workload()
        flat, pod = _run_pod_pair(
            period, dec, max_rounds=30, gossip_mode="gated",
            cross_pod_every_k=1, cross_pod_top_k=1,
        )
        assert pod.final_certificates == flat.final_certificates
        assert pod.history == flat.history
        assert pod.messages_accepted == flat.messages_accepted

    def test_k1_fail_stop_and_laggard_identical(self):
        period, dec = self._workload()
        w = self.W
        speed = [1.0] * (w - 2) + [0.25, 0.5]
        fail = [5] + [10**6] * (w - 1)
        flat, pod = _run_pod_pair(
            period, dec, speed=speed, fail_round=fail, max_rounds=25,
            gossip_mode="dense", cross_pod_every_k=1, cross_pod_top_k=1,
        )
        assert pod.final_certificates == flat.final_certificates
        assert pod.history == flat.history
        assert pod.rounds == flat.rounds == 25

    def test_k1_chunked_dispatch_identical(self):
        period, dec = self._workload()
        w = self.W
        pod_mesh = _pod_mesh_or_skip()
        runs = {}
        for rpd in (1, 8):
            runs[rpd] = make_engine(
                ShardableToyWorker(period, dec),
                EngineConfig(n_workers=w, mesh=pod_mesh, rounds_per_dispatch=rpd,
                             max_rounds=24, cross_pod_every_k=1, cross_pod_top_k=1),
            ).run()
        assert runs[8].final_certificates == runs[1].final_certificates
        assert runs[8].history == runs[1].history

    def test_k_gt_1_is_measured_approximation(self):
        """k>1 trades DCN traffic for staleness: the run must stay
        protocol-sound (monotone certs, nothing diverges) and the
        amortized DCN footprint must fall ~k-fold; end-state equality is
        NOT asserted — bench_scaling.py measures the divergence."""
        period, dec = self._workload()
        pod_mesh = _pod_mesh_or_skip()
        w = self.W
        runs = {}
        for k in (1, 8):
            runs[k] = make_engine(
                ShardableToyWorker(period, dec),
                EngineConfig(n_workers=w, mesh=pod_mesh, max_rounds=30,
                             cross_pod_every_k=k, cross_pod_top_k=1),
            ).run()
        assert runs[8].gossip_bytes_per_round_dcn * 8 == runs[1].gossip_bytes_per_round_dcn * 1
        assert runs[8].messages_sent_dcn < runs[1].messages_sent_dcn
        # certificates only ever improve, even with an 8-round-stale DCN
        assert all(c <= 0.0 for c in runs[8].final_certificates)
        # intra-pod tier is untouched by k
        assert runs[8].gossip_bytes_per_round_ici == runs[1].gossip_bytes_per_round_ici

    def test_traffic_tier_accounting(self):
        period, dec = self._workload()
        w = self.W
        pod_mesh = _pod_mesh_or_skip()
        n_dev = pod_mesh.shape["pod"] * pod_mesh.shape["workers"]
        wpp = pod_mesh.shape["workers"]
        w_pod = w // pod_mesh.shape["pod"]
        p = 8  # toy payload
        # pinned dense control: these are the dense-control tier formulas
        res = make_engine(
            ShardableToyWorker(period, dec),
            EngineConfig(n_workers=w, mesh=pod_mesh, max_rounds=10,
                         gossip_mode="dense", cross_pod_every_k=4,
                         cross_pod_top_k=2, control_plane="dense"),
        ).run()
        # intra tier: dense all_gather of the POD's workers only
        assert res.gossip_bytes_per_round_ici == w_pod * (p + 4 + 1)
        # cross tier: top-2 per device of (payload + f32 cert + i32 id),
        # amortized over k=4
        assert res.gossip_bytes_per_round_dcn == n_dev * 2 * (p + 4 + 4) // 4
        assert res.gossip_bytes_per_round == (
            res.gossip_bytes_per_round_ici + res.gossip_bytes_per_round_dcn
        )
        # gated intra tier shrinks the ICI leg to per-device candidates
        gated = make_engine(
            ShardableToyWorker(period, dec),
            EngineConfig(n_workers=w, mesh=pod_mesh, max_rounds=10,
                         gossip_mode="gated", cross_pod_every_k=4,
                         cross_pod_top_k=2, control_plane="dense"),
        ).run()
        assert gated.gossip_bytes_per_round_ici == w_pod * 5 + wpp * 1 * (p + 4)
        # counter split: every push is attributed to exactly one tier
        assert res.messages_sent_dcn > 0
        assert res.messages_sent > res.messages_sent_dcn

    def test_sparrow_pod_k1_identical_to_flat(self, small_data):
        """The real batched Sparrow worker through the two-tier mesh:
        bit-identical to the flat all-device engine at k=1."""
        xtr, ytr, _, _ = small_data
        pod_mesh = _pod_mesh_or_skip()
        w = 16
        cfg = _sparrow_cfg(
            w,
            sample_size=256,
            capacity=16,
            scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25),
        )
        ecfg = dict(n_workers=w, max_rounds=30, seed=0,
                    cross_pod_every_k=1, cross_pod_top_k=1)
        flat = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg, mesh=_mesh_for(w))
        ).run()
        pod = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg, mesh=pod_mesh)
        ).run()
        _assert_same_run(flat, pod, check_sent=False)
        assert pod.history == flat.history
        assert min(pod.final_certificates) < 0.0  # actually learned

    def test_env_defaults_flow_into_pod_engine(self):
        """No explicit cross-pod args: the engine follows the REPRO_*
        env defaults (the CI pod matrix leg sets an approximating k), so
        only env-insensitive invariants are asserted."""
        period, dec = self._workload()
        pod_mesh = _pod_mesh_or_skip()
        res = make_engine(
            ShardableToyWorker(period, dec),
            EngineConfig(n_workers=self.W, mesh=pod_mesh, max_rounds=20),
        ).run()
        assert res.gossip_bytes_per_round_dcn > 0
        assert all(c <= 0.0 for c in res.final_certificates)
        assert res.messages_sent >= res.messages_sent_dcn > 0

    def test_rejects_bad_pod_axis_order(self):
        n = len(jax.devices())
        if n < 4 or n % 2:
            pytest.skip("needs >= 4 devices, even count")
        toy = ShardableToyWorker([1] * 8, [0.1] * 8)
        bad = jax.make_mesh((n // 2, 2), ("workers", "pod"))
        with pytest.raises(ValueError, match="axes"):
            make_engine(toy, EngineConfig(n_workers=8, mesh=bad))

    def test_rejects_bad_cross_pod_knobs(self):
        toy = ShardableToyWorker([1] * 8, [0.1] * 8)
        with pytest.raises(ValueError, match="cross_pod_every_k"):
            make_engine(toy, EngineConfig(n_workers=8, mesh=_mesh_for(8),
                                          cross_pod_every_k=0))
        with pytest.raises(ValueError, match="cross_pod_top_k"):
            make_engine(toy, EngineConfig(n_workers=8, mesh=_mesh_for(8),
                                          cross_pod_top_k=0))


class TestFactory:
    def test_none_and_single_device_mesh_fall_back(self):
        toy = ShardableToyWorker([1] * 4, [0.1] * 4)
        eng = make_engine(toy, EngineConfig(n_workers=4, mesh=None))
        assert type(eng) is TMSNEngine
        eng = make_engine(toy, EngineConfig(n_workers=4, mesh=make_worker_mesh(1)))
        assert type(eng) is TMSNEngine

    def test_rejects_bad_mesh(self):
        toy = ShardableToyWorker([1] * 4, [0.1] * 4)
        bad = jax.make_mesh((len(jax.devices()),), ("data",))
        with pytest.raises(ValueError, match="workers"):
            make_engine(toy, EngineConfig(n_workers=4, mesh=bad))

    def test_rejects_indivisible_worker_count(self):
        n = len(jax.devices())
        w = n + 1  # never divisible by n >= 2
        toy = ShardableToyWorker([1] * w, [0.1] * w)
        with pytest.raises(ValueError, match="divide"):
            make_engine(toy, EngineConfig(n_workers=w, mesh=make_worker_mesh(n)))


# ---------------------------------------------------------------------------
# The real batched Sparrow worker through the sharded engine
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_data():
    xb, y, _ = make_splice_like(SpliceConfig(n=20_000, d=16, num_bins=8, seed=3))
    return train_test_split(xb, y)


def _sparrow_cfg(w, **kw):
    base = dict(
        sample_size=1024,
        capacity=48,
        scanner=ScannerConfig(chunk_size=256, num_bins=8, gamma0=0.25),
        n_workers=w,
    )
    base.update(kw)
    return SparrowConfig(**base)


def _assert_same_run(res1, res8, check_sent=True):
    """check_sent=False for gated-vs-dense pairs: gating pushes fewer
    messages by design while end states (and adoptions) must match."""
    assert res8.final_certificates == res1.final_certificates
    if check_sent:
        assert res8.messages_sent == res1.messages_sent
    assert res8.messages_accepted == res1.messages_accepted
    for m1, m8 in zip(res1.final_models, res8.final_models):
        assert int(m8.count) == int(m1.count)
        np.testing.assert_array_equal(np.asarray(m8.feat), np.asarray(m1.feat))
        np.testing.assert_array_equal(np.asarray(m8.alpha), np.asarray(m1.alpha))


class TestSparrowEquivalence:
    def test_scan_and_gossip_identical(self, small_data):
        xtr, ytr, _, _ = small_data
        w = 8
        cfg = _sparrow_cfg(w)
        # pinned dense: strict traffic equality vs the single-device
        # engine (the gated CI leg would push fewer at W_local > 1)
        ecfg = dict(n_workers=w, max_rounds=50, seed=0, gossip_mode="dense",
                    control_plane="dense")
        res1 = TMSNEngine(BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg)).run()
        res8 = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg, mesh=_mesh_for(w))
        ).run()
        _assert_same_run(res1, res8)
        assert min(res8.final_certificates) < 0.0  # actually learned

    def test_resample_path_identical(self, small_data):
        """Aggressive ESS threshold forces the lax.map resample path
        inside the shard-mapped step; RNG streams live in the sharded
        state so redraws must stay bit-identical."""
        xtr, ytr, _, _ = small_data
        w = 4
        cfg = _sparrow_cfg(w, ess_threshold=0.9)
        ecfg = dict(n_workers=w, max_rounds=40, seed=0, gossip_mode="dense",
                    control_plane="dense")
        res1 = TMSNEngine(BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg)).run()
        res8 = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg, mesh=_mesh_for(w))
        ).run()
        _assert_same_run(res1, res8)

    def test_heterogeneous_identical(self, small_data):
        """Laggard + fail-stop + jittered link delays, both substrates."""
        xtr, ytr, _, _ = small_data
        w = 8
        cfg = _sparrow_cfg(w)
        speed = np.ones(w)
        speed[-1] = 0.25
        fail = np.full(w, 10**6)
        fail[-2] = 15
        delays = quantize_latency(0.05, 0.02, 0.05, w, seed=1)
        ecfg = dict(
            n_workers=w, delay_rounds=delays, speed=speed, fail_round=fail,
            max_rounds=40, seed=0, gossip_mode="dense", control_plane="dense",
        )
        res1 = TMSNEngine(BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg)).run()
        res8 = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg, mesh=_mesh_for(w))
        ).run()
        _assert_same_run(res1, res8)

    def test_kernel_scan_path_identical(self, small_data):
        """ScannerConfig.use_kernel routes the sharded scan through the
        vmapped Pallas edge_scan inside shard_map."""
        xtr, ytr, _, _ = small_data
        w = 4
        cfg = _sparrow_cfg(
            w,
            sample_size=256,
            capacity=16,
            scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25, use_kernel=True),
        )
        ecfg = dict(n_workers=w, max_rounds=12, seed=0, gossip_mode="dense",
                    control_plane="dense")
        res1 = TMSNEngine(BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg)).run()
        res8 = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg), EngineConfig(**ecfg, mesh=_mesh_for(w))
        ).run()
        _assert_same_run(res1, res8)

    def test_gated_gossip_identical_uniform_delay(self, small_data):
        """Real payloads through the top-k export hook: gated must equal
        dense exactly under uniform delay (W > devices so several
        workers share a shard and gating actually drops payloads)."""
        xtr, ytr, _, _ = small_data
        w = 16
        cfg = _sparrow_cfg(
            w,
            sample_size=256,
            capacity=16,
            scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25),
        )
        ecfg = dict(n_workers=w, max_rounds=30, seed=0)
        results = {}
        for mode in ("dense", "gated"):
            results[mode] = make_engine(
                BatchedSparrowWorker(xtr, ytr, cfg),
                EngineConfig(**ecfg, mesh=_mesh_for(w), gossip_mode=mode),
            ).run()
        _assert_same_run(results["dense"], results["gated"], check_sent=False)
        assert results["gated"].history == results["dense"].history
        assert min(results["gated"].final_certificates) < 0.0  # actually learned
        # the payload leg shrank from W models to n_dev candidates
        assert (
            results["gated"].gossip_bytes_per_round
            < results["dense"].gossip_bytes_per_round
        )

    def test_gated_kernel_scan_path_identical(self, small_data):
        """Gated gossip + chunked dispatch + the Pallas edge_scan path
        together, against the dense unchunked run."""
        xtr, ytr, _, _ = small_data
        w = 16
        cfg = _sparrow_cfg(
            w,
            sample_size=256,
            capacity=16,
            scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25, use_kernel=True),
        )
        ecfg = dict(n_workers=w, max_rounds=12, seed=0)
        resd = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg),
            EngineConfig(**ecfg, mesh=_mesh_for(w), gossip_mode="dense",
                         rounds_per_dispatch=1),
        ).run()
        resg = make_engine(
            BatchedSparrowWorker(xtr, ytr, cfg),
            EngineConfig(**ecfg, mesh=_mesh_for(w), gossip_mode="gated",
                         rounds_per_dispatch=4),
        ).run()
        _assert_same_run(resd, resg, check_sent=False)
