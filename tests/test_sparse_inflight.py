"""Sparse pending-queue in-flight state vs the dense oracle.

`EngineConfig.inflight_capacity = C > 0` swaps the dense ``(W, W, D)``
in-flight certificate buffer for a bounded per-destination ``(W, C)``
pending queue and routes the round hot path through the fused
``kernels/round_step.py`` kernel. The contract under test:

  * at sufficient capacity (C >= peak per-destination occupancy) the
    sparse engine is BIT-IDENTICAL to the dense oracle — certificates,
    history, adoptions, traffic counters, fail-stop, laggard credit,
    heterogeneous delay matrices — on the single-device engine, the
    sharded engine (dense and gated gossip), and the pod mesh (both
    tiers); ``messages_evicted == 0`` is the run-level witness;
  * both `round_step_impl` values ("pallas" in interpret mode, "ref")
    produce identical runs;
  * at small C eviction is worst-certificate-first and exactly
    accounted: every offered-but-not-retained candidate lands in
    ``messages_evicted`` (discards shift from delivery time to push
    time, so dense_discarded == sparse_discarded + sparse_evicted).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.boosting import BatchedSparrowWorker, SparrowConfig
from repro.boosting.scanner import ScannerConfig
from repro.core.engine import (
    EngineConfig,
    PendingQueue,
    TMSNEngine,
    _empty_queue,
    _queue_push,
    make_engine,
    quantize_latency,
)
from repro.core.engine_sharded import sharded_engine_available
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split
from repro.launch.mesh import make_worker_mesh
from test_sharded_engine import ShardableToyWorker

W = 16
IMPLS = ("ref", "pallas")


def _toy(w=W):
    return ShardableToyWorker(
        [1, 2, 3, 10**9] * (w // 4), [0.01 * (i + 1) for i in range(w)]
    )


def _run(cap, impl="ref", mesh=None, w=W, worker=None, **cfg):
    eng = make_engine(
        worker if worker is not None else _toy(w),
        EngineConfig(
            n_workers=w,
            max_rounds=cfg.pop("max_rounds", 30),
            inflight_capacity=cap,
            round_step_impl=impl,
            # identity tests compare runs across in-flight representations,
            # where offer-side counters are only comparable on clean traffic
            # — the CI chaos leg must not inject here
            fault_spec=cfg.pop("fault_spec", ""),
            mesh=mesh,
            **cfg,
        ),
    )
    return eng.run()


def _assert_identical(dense, sparse):
    """Full-capacity contract: indistinguishable runs, zero evictions."""
    assert sparse.final_certificates == dense.final_certificates
    assert sparse.history == dense.history
    assert sparse.rounds == dense.rounds
    assert sparse.messages_sent == dense.messages_sent
    assert sparse.messages_accepted == dense.messages_accepted
    assert sparse.messages_discarded == dense.messages_discarded
    assert sparse.messages_sent_dcn == dense.messages_sent_dcn
    assert sparse.messages_evicted == 0
    assert sparse.inflight_occupancy_peak > 0


HET = dict(
    speed=[1.0, 0.25] * (W // 2),
    fail_round=[10**6] * (W - 1) + [12],
    eps=0.005,
)


class TestSingleDevice:
    @pytest.mark.parametrize("impl", IMPLS)
    def test_uniform_delay_identical(self, impl):
        _assert_identical(_run(0), _run(8, impl=impl))

    @pytest.mark.parametrize("impl", IMPLS)
    def test_heterogeneous_identical(self, impl):
        """Delay matrix + fail-stop + laggard speeds + nonzero eps in
        one config; capacity covers the multi-cohort occupancy."""
        delays = quantize_latency(0.05, 0.02, 0.01, W, seed=0)
        assert int(delays.max()) > int(delays.min())  # cohorts really mix
        d = _run(0, delay_rounds=delays, **HET)
        s = _run(64, impl=impl, delay_rounds=delays, **HET)
        _assert_identical(d, s)

    def test_impls_bit_identical(self):
        a = _run(8, impl="ref")
        b = _run(8, impl="pallas")
        assert a.final_certificates == b.final_certificates
        assert a.history == b.history

    def test_chunked_dispatch_identical(self):
        _assert_identical(
            _run(0, rounds_per_dispatch=8), _run(8, rounds_per_dispatch=8)
        )

    def test_occupancy_peak_is_a_sufficient_capacity(self):
        """Rerunning at C = reported occ peak must still be exact — the
        peak is the measured capacity floor it claims to be."""
        delays = quantize_latency(0.05, 0.02, 0.01, W, seed=0)
        d = _run(0, delay_rounds=delays, **HET)
        s = _run(64, delay_rounds=delays, **HET)
        peak = s.inflight_occupancy_peak
        assert 0 < peak < 64
        _assert_identical(d, _run(peak, delay_rounds=delays, **HET))


class TestOverflow:
    def test_small_capacity_accounting_exact(self):
        """C=1 at uniform delay: the global min survives worst-first
        eviction so certificates/history still match, and every dropped
        candidate is accounted (discards shift to push time). Pinned to
        dense control: sparse control ships only top-k candidates, so a
        C=1 queue never overflows and the premise (evictions happen)
        would not hold under the sparse-control CI leg."""
        d = _run(0, control_plane="dense")
        s = _run(1, control_plane="dense")
        assert s.final_certificates == d.final_certificates
        assert s.history == d.history
        assert s.messages_evicted > 0
        assert s.messages_sent == d.messages_sent
        assert s.messages_accepted == d.messages_accepted
        assert s.messages_discarded + s.messages_evicted == d.messages_discarded

    def test_queue_push_eviction_order_c1(self):
        """Worst-certificate-first at C=1: the kept entry is the best
        (cert, src) candidate; the resident entry is evicted when a
        strictly better candidate arrives and retained otherwise."""
        delay = jnp.ones((2, 4), jnp.int32)
        occupied = PendingQueue(
            cert=jnp.asarray([[-5.0], [-1.0]], jnp.float32),
            src=jnp.asarray([[3], [3]], jnp.int32),
            due=jnp.asarray([[7], [7]], jnp.int32),
            slot=jnp.asarray([[0], [0]], jnp.int32),
        )
        # src 2 broadcasts cert -3: worse than dst0's resident -5
        # (candidate dropped), better than dst1's resident -1 (evicted)
        score = jnp.full((4,), jnp.inf).at[2].set(-3.0)
        q, n_pushed, n_evicted, occ, _, _ = _queue_push(
            occupied, score, jnp.ones((2,), bool), jnp.asarray([0, 1]), delay,
            jnp.int32(4), 8,
        )
        np.testing.assert_array_equal(np.asarray(q.cert[:, 0]), [-5.0, -3.0])
        np.testing.assert_array_equal(np.asarray(q.src[:, 0]), [3, 2])
        np.testing.assert_array_equal(np.asarray(q.due[:, 0]), [7, 5])
        assert int(n_pushed) == 2  # offered to both destinations
        assert int(n_evicted) == 2  # candidate@dst0 + resident@dst1
        assert int(occ) == 2

    def test_queue_push_tie_drops_higher_src(self):
        """Equal certs: eviction keeps the lower source id — the entry
        the dense delivery argmin would pick on a tie."""
        q0 = _empty_queue(1, 1)._replace(
            cert=jnp.asarray([[-2.0]], jnp.float32),
            src=jnp.asarray([[3]], jnp.int32),
            due=jnp.asarray([[9]], jnp.int32),
        )
        score = jnp.full((4,), jnp.inf).at[1].set(-2.0)
        q, _, n_evicted, _, _, _ = _queue_push(
            q0, score, jnp.ones((1,), bool), jnp.asarray([0]),
            jnp.ones((1, 4), jnp.int32), jnp.int32(0), 8,
        )
        assert int(q.src[0, 0]) == 1 and int(n_evicted) == 1

    def test_self_and_dead_rows_never_enqueue(self):
        q0 = _empty_queue(2, 2)
        score = jnp.asarray([-1.0, -2.0], jnp.float32)  # both broadcast
        alive = jnp.asarray([True, False])
        q, n_pushed, n_evicted, occ, _, _ = _queue_push(
            q0, score, alive, jnp.asarray([0, 1]),
            jnp.ones((2, 2), jnp.int32), jnp.int32(0), 8,
        )
        # dst 0 hears only src 1; dst 1 is dead and hears nothing
        assert int(jnp.sum(jnp.isfinite(q.cert[0]))) == 1
        assert int(q.src[0, 0]) == 1
        assert int(jnp.sum(jnp.isfinite(q.cert[1]))) == 0
        assert int(n_pushed) == 1 and int(n_evicted) == 0 and int(occ) == 1


@pytest.mark.skipif(
    not sharded_engine_available(), reason="sparse sharded tests need >=2 devices"
)
class TestSharded:
    @pytest.mark.parametrize("mode", ["dense", "gated"])
    @pytest.mark.parametrize("impl", IMPLS)
    def test_uniform_identical(self, mode, impl):
        mesh = make_worker_mesh()
        d = _run(0, mesh=mesh, gossip_mode=mode)
        s = _run(64, impl=impl, mesh=mesh, gossip_mode=mode)
        _assert_identical(d, s)

    def test_heterogeneous_identical(self):
        mesh = make_worker_mesh()
        delays = quantize_latency(0.05, 0.02, 0.01, W, seed=0)
        d = _run(0, mesh=mesh, gossip_mode="dense", delay_rounds=delays, **HET)
        s = _run(64, mesh=mesh, gossip_mode="dense", delay_rounds=delays, **HET)
        _assert_identical(d, s)

    def test_sharded_sparse_matches_single_device_sparse(self):
        a = _run(32)
        b = _run(32, mesh=make_worker_mesh())
        assert b.final_certificates == a.final_certificates
        assert b.history == a.history
        assert b.messages_evicted == a.messages_evicted == 0


@pytest.mark.skipif(
    len(jax.devices()) < 4 or len(jax.devices()) % 2,
    reason="pod-mesh sparse tests need an even device count >= 4",
)
class TestPodMesh:
    @pytest.mark.parametrize("mode", ["dense", "gated"])
    @pytest.mark.parametrize("every_k", [1, 2])
    def test_both_tiers_identical(self, mode, every_k):
        mesh = make_worker_mesh(pods=2)
        kw = dict(gossip_mode=mode, cross_pod_every_k=every_k)
        d = _run(0, mesh=mesh, **kw)
        s = _run(64, mesh=mesh, **kw)
        _assert_identical(d, s)


def _assert_same_protocol(dense, sparse):
    """Cross-CONTROL-PLANE contract: the protocol outcome (certificates,
    history, rounds, adoptions) is identical under uniform delay, but
    `messages_sent`/`messages_discarded` are deliberately NOT compared —
    sparse control never puts suppressed runner-ups on the wire, so
    those counters legitimately shrink (docs/architecture.md)."""
    assert sparse.final_certificates == dense.final_certificates
    assert sparse.history == dense.history
    assert sparse.rounds == dense.rounds
    assert sparse.messages_accepted == dense.messages_accepted


class TestControlPlane:
    """`control_plane="sparse"` (top-k candidate triples instead of the
    dense certs/flags exchange) vs dense control, on every substrate ×
    both in-flight representations. Uniform delay throughout: that is
    the exactness regime; het delay is `bench_scaling.py`'s measured
    territory."""

    @pytest.mark.parametrize("cap", [0, 8])
    @pytest.mark.parametrize("impl", IMPLS)
    def test_single_device_identical(self, cap, impl):
        d = _run(cap, impl=impl)
        s = _run(cap, impl=impl, control_plane="sparse")
        _assert_same_protocol(d, s)
        assert s.control_plane == "sparse"

    def test_single_device_het_speeds_failstop_identical(self):
        """Laggard speeds + a fail-stop + nonzero eps (still uniform
        delay — the sparse-control exactness precondition)."""
        for cap in (0, 64):
            d = _run(cap, **HET)
            s = _run(cap, control_plane="sparse", **HET)
            _assert_same_protocol(d, s)

    def test_top_k_wider_than_improvers_identical(self):
        _assert_same_protocol(
            _run(0, gossip_top_k=3), _run(0, gossip_top_k=3, control_plane="sparse")
        )

    @pytest.mark.skipif(
        not sharded_engine_available(),
        reason="sharded control-plane tests need >=2 devices",
    )
    @pytest.mark.parametrize("mode", ["dense", "gated"])
    @pytest.mark.parametrize("cap", [0, 64])
    def test_sharded_identical(self, mode, cap):
        mesh = make_worker_mesh()
        d = _run(cap, mesh=mesh, gossip_mode=mode, **HET)
        s = _run(cap, mesh=mesh, gossip_mode=mode, control_plane="sparse", **HET)
        _assert_same_protocol(d, s)

    @pytest.mark.skipif(
        not sharded_engine_available(),
        reason="sharded control-plane tests need >=2 devices",
    )
    def test_control_bytes_accounting(self):
        """The reported control-plane footprint is the exact formula:
        dense W_tier·5 (f32 cert + bool flag per worker), sparse
        n_dev·k·12 ((cert, id, round) triples) — and the single-device
        engine reports 0 (no wire)."""
        mesh = make_worker_mesh()
        n_dev = len(jax.devices())
        d = _run(0, mesh=mesh, gossip_mode="gated", control_plane="dense")
        s = _run(0, mesh=mesh, gossip_mode="gated", control_plane="sparse")
        assert d.control_bytes_per_round == W * 5
        assert s.control_bytes_per_round == n_dev * 1 * 12
        assert d.control_plane == "dense"
        local = _run(0, control_plane="sparse")
        assert local.control_bytes_per_round == 0

    @pytest.mark.skipif(
        len(jax.devices()) < 4 or len(jax.devices()) % 2,
        reason="pod-mesh control-plane tests need an even device count >= 4",
    )
    @pytest.mark.parametrize("mode", ["dense", "gated"])
    @pytest.mark.parametrize("cap", [0, 64])
    def test_pod_mesh_identical(self, mode, cap):
        mesh = make_worker_mesh(pods=2)
        kw = dict(gossip_mode=mode, cross_pod_every_k=2, cross_pod_top_k=2)
        d = _run(cap, mesh=mesh, **kw)
        s = _run(cap, mesh=mesh, control_plane="sparse", **kw)
        _assert_same_protocol(d, s)

    def test_single_device_matches_sharded_sparse_control(self):
        """Sparse control composes with the sharded/unsharded
        equivalence chain: the same config lands on the same protocol
        outcome on both substrates."""
        a = _run(8, control_plane="sparse")
        if not sharded_engine_available():
            pytest.skip("needs >=2 devices for the sharded half")
        b = _run(8, mesh=make_worker_mesh(), gossip_mode="gated",
                 control_plane="sparse")
        assert b.final_certificates == a.final_certificates
        assert b.history == a.history


class TestControlPlaneWorkers:
    """Sparse vs dense control under the PRODUCTION workers (real
    payload rings, adoptions, resamples) — Sparrow and the TMSN-SGD
    transformer."""

    @pytest.fixture(scope="class")
    def small_data(self):
        xb, y, _ = make_splice_like(SpliceConfig(n=20_000, d=16, num_bins=8, seed=3))
        return train_test_split(xb, y)

    def _sparrow(self, small_data, w):
        xtr, ytr, _, _ = small_data
        cfg = SparrowConfig(
            sample_size=256,
            capacity=16,
            scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25),
            n_workers=w,
        )
        return BatchedSparrowWorker(xtr, ytr, cfg)

    @pytest.mark.parametrize("cap", [0, 16])
    def test_sparrow_identical(self, small_data, cap):
        w = 4
        runs = {}
        for plane in ("dense", "sparse"):
            runs[plane] = _run(
                cap, w=w, worker=self._sparrow(small_data, w),
                control_plane=plane, max_rounds=12, seed=0,
            )
        _assert_same_protocol(runs["dense"], runs["sparse"])

    @pytest.mark.skipif(
        not sharded_engine_available(),
        reason="sharded Sparrow control-plane test needs >=2 devices",
    )
    def test_sparrow_sharded_gated_identical(self, small_data):
        w = 8
        mesh = make_worker_mesh()
        runs = {}
        for plane in ("dense", "sparse"):
            runs[plane] = _run(
                16, w=w, worker=self._sparrow(small_data, w), mesh=mesh,
                gossip_mode="gated", control_plane=plane, max_rounds=12, seed=0,
            )
        _assert_same_protocol(runs["dense"], runs["sparse"])

    def test_sgd_identical(self):
        from test_worker_contract import _sgd_worker

        runs = {}
        for plane in ("dense", "sparse"):
            runs[plane] = _run(
                0, w=4, worker=_sgd_worker(), control_plane=plane,
                max_rounds=8, seed=0,
            )
        _assert_same_protocol(runs["dense"], runs["sparse"])

    @pytest.mark.skipif(
        not sharded_engine_available(),
        reason="sharded SGD control-plane test needs >=2 devices",
    )
    def test_sgd_sharded_gated_identical(self):
        from test_worker_contract import _sgd_worker

        mesh = make_worker_mesh()
        runs = {}
        for plane in ("dense", "sparse"):
            runs[plane] = _run(
                8, w=8, worker=_sgd_worker(), mesh=mesh, gossip_mode="gated",
                control_plane=plane, max_rounds=8, seed=0,
            )
        _assert_same_protocol(runs["dense"], runs["sparse"])


class TestAutoCapacity:
    """`inflight_capacity="auto"`: a warm-up occupancy probe sizes the
    pending queues (peak × headroom), the choice lands in
    `SimResult.inflight_capacity_selected`, and the run is bit-identical
    to an explicit-capacity rerun AND to the dense oracle."""

    def test_auto_selects_and_matches_explicit(self):
        delays = quantize_latency(0.05, 0.02, 0.01, W, seed=0)
        auto = _run("auto", delay_rounds=delays, **HET)
        sel = auto.inflight_capacity_selected
        assert sel > 0
        explicit = _run(sel, delay_rounds=delays, **HET)
        assert explicit.inflight_capacity_selected == 0  # explicit: not auto
        assert auto.final_certificates == explicit.final_certificates
        assert auto.history == explicit.history
        assert auto.messages_evicted == explicit.messages_evicted == 0

    def test_auto_exact_vs_dense_oracle(self):
        delays = quantize_latency(0.05, 0.02, 0.01, W, seed=0)
        d = _run(0, delay_rounds=delays, **HET)
        a = _run("auto", delay_rounds=delays, **HET)
        _assert_identical(d, a)

    def test_auto_via_env_var(self, monkeypatch):
        monkeypatch.setenv("REPRO_INFLIGHT_CAPACITY", "auto")
        cfg = EngineConfig(n_workers=W, max_rounds=30, fault_spec="")
        assert cfg.inflight_capacity == "auto"
        res = make_engine(_toy(), cfg).run()
        assert res.inflight_capacity_selected > 0
        _assert_identical(_run(0), res)

    @pytest.mark.skipif(
        not sharded_engine_available(),
        reason="sharded auto-capacity test needs >=2 devices",
    )
    def test_auto_sharded_with_sparse_control(self):
        """The CI sparse-control leg's exact combination: gated gossip +
        sparse control + auto capacity on the sharded engine."""
        mesh = make_worker_mesh()
        kw = dict(mesh=mesh, gossip_mode="gated", control_plane="sparse")
        d = _run(0, gossip_mode="gated", mesh=mesh, **HET)
        a = _run("auto", **kw, **HET)
        assert a.inflight_capacity_selected > 0
        _assert_same_protocol(d, a)
        explicit = _run(a.inflight_capacity_selected, **kw, **HET)
        assert a.final_certificates == explicit.final_certificates
        assert a.history == explicit.history


class TestSparrow:
    @pytest.fixture(scope="class")
    def small_data(self):
        xb, y, _ = make_splice_like(SpliceConfig(n=20_000, d=16, num_bins=8, seed=3))
        return train_test_split(xb, y)

    def _worker(self, small_data, w):
        xtr, ytr, _, _ = small_data
        cfg = SparrowConfig(
            sample_size=256,
            capacity=16,
            scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25),
            n_workers=w,
        )
        return BatchedSparrowWorker(xtr, ytr, cfg)

    @pytest.mark.parametrize("impl", IMPLS)
    def test_real_worker_identical(self, small_data, impl):
        """The full Sparrow worker (real adoptions, resamples, payload
        ring lookups) under sparse vs dense in-flight state."""
        w = 4
        runs = {}
        for cap in (0, 16):
            runs[cap] = _run(
                cap, impl=impl, w=w, worker=self._worker(small_data, w),
                max_rounds=12, seed=0,
            )
        _assert_identical(runs[0], runs[16])

    @pytest.mark.skipif(
        not sharded_engine_available(),
        reason="sharded Sparrow sparse test needs >=2 devices",
    )
    def test_real_worker_sharded_gated_identical(self, small_data):
        w = 8
        mesh = make_worker_mesh()
        runs = {}
        for cap in (0, 16):
            runs[cap] = _run(
                cap, w=w, worker=self._worker(small_data, w), mesh=mesh,
                gossip_mode="gated", max_rounds=12, seed=0,
            )
        _assert_identical(runs[0], runs[16])
