"""Tests for the vectorized round-based TMSN engine and the batched
Sparrow worker.

Equivalence strategy (DESIGN: the event sim is the fidelity-1 oracle):

  * protocol level — a deterministic toy worker runs under BOTH
    substrates on a uniform-speed, zero-latency config with the same
    seeds; final certificates (and message counters) must be identical;
  * computation level — the batched Sparrow worker must reproduce the
    unbatched ``SparrowWorker`` segment-for-segment (same RNG streams),
    including the resample path and the Pallas kernel scan path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.boosting import BatchedSparrowWorker, SparrowConfig, SparrowWorker
from repro.boosting.batched_sparrow import common_prefix_len
from repro.boosting.scanner import ScannerConfig
from repro.boosting.sparrow import feature_ownership_masks
from repro.core.engine import EngineConfig, TMSNEngine, quantize_latency
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split


# ---------------------------------------------------------------------------
# Toy worker: fires every ``period[i]`` segments; its own certificate path
# after f fires is ``-dec[i] * f``; adoption takes the min. The final
# certificates depend only on which messages were delivered, so the toy
# pins the engine's gossip semantics against the event simulator.
# ---------------------------------------------------------------------------


class ToySimWorker:
    def __init__(self, period, dec):
        self.period = list(period)
        self.dec = list(dec)

    def init_state(self, worker_id, seed):
        return {"wid": worker_id, "segs": 0, "fires": 0, "cert": 0.0, "from": -1}

    def run_segment(self, s):
        s = dict(s)
        s["segs"] += 1
        fired = s["segs"] % self.period[s["wid"]] == 0
        if fired:
            s["fires"] += 1
            # float32 arithmetic so final certs are bit-identical to the
            # engine's array math
            own = float(-(np.float32(self.dec[s["wid"]]) * np.float32(s["fires"])))
            s["cert"] = min(s["cert"], own)
        return s, 1.0, fired

    def certificate(self, s):
        return s["cert"]

    def export_model(self, s):
        return {"owner": s["wid"], "cert": s["cert"]}

    def adopt(self, s, model, certificate):
        s = dict(s)
        s["cert"] = float(certificate)
        s["from"] = int(model["owner"])
        return s

    def payload_bytes(self, model):
        return 8


class ToyBatchedWorker:
    def __init__(self, period, dec):
        self.period = jnp.asarray(period, jnp.int32)
        self.dec = jnp.asarray(dec, jnp.float32)

    def init_batch(self, n_workers, seed):
        z = jnp.zeros((n_workers,), jnp.int32)
        return {
            "segs": z,
            "fires": z,
            "cert": jnp.zeros((n_workers,), jnp.float32),
            "from": jnp.full((n_workers,), -1, jnp.int32),
        }

    def scan_round(self, state, mask):
        segs = state["segs"] + mask.astype(jnp.int32)
        fired = mask & (segs % self.period == 0)
        fires = state["fires"] + fired.astype(jnp.int32)
        own = -self.dec * fires
        cert = jnp.where(fired, jnp.minimum(state["cert"], own), state["cert"])
        new = {"segs": segs, "fires": fires, "cert": cert, "from": state["from"]}
        return new, mask.astype(jnp.float32), fired

    def needs_resample(self, state):
        return jnp.zeros(state["cert"].shape, bool)

    def resample_round(self, state, do):
        return state, jnp.zeros(state["cert"].shape, jnp.float32)

    def certificates(self, state):
        return state["cert"]

    def export_models(self, state):
        w = state["cert"].shape[0]
        return {
            "owner": jnp.arange(w, dtype=jnp.int32),
            "cert": state["cert"],
            "adopted_from": state["from"],
        }

    def adopt_batch(self, state, models, certs, take):
        new = dict(state)
        new["cert"] = jnp.where(take, certs, state["cert"])
        new["from"] = jnp.where(take, models["owner"], state["from"])
        return new, jnp.zeros(state["cert"].shape, jnp.float32)

    def payload_bytes(self):
        return 8


class TestEngineSimulatorEquivalence:
    def test_single_sender_identical_final_certificates(self):
        """Uniform speeds, zero latency, same seeds: the engine and the
        event simulator must end on IDENTICAL final certificates."""
        w = 4
        period = [1, 10**9, 10**9, 10**9]
        dec = [0.1] * w
        target = -0.95

        sim = TMSNSimulator(
            ToySimWorker(period, dec),
            [WorkerSpec(speed=1.0) for _ in range(w)],
            SimulatorConfig(
                n_workers=w,
                base_latency=0.0,
                latency_jitter=0.0,
                target_certificate=target,
                max_events=10_000,
                seed=0,
            ),
        )
        res_sim = sim.run()

        eng = TMSNEngine(
            ToyBatchedWorker(period, dec),
            EngineConfig(
                n_workers=w, delay_rounds=1, target_certificate=target, max_rounds=500,
                # exact-accounting comparison against the fault-free event
                # simulator — the CI chaos leg must not inject here
                fault_spec="",
            ),
        )
        res_eng = eng.run()

        assert res_eng.final_certificates == res_sim.final_certificates
        # w0 needed 10 fires to cross the target; everyone saw its 9th
        np.testing.assert_allclose(
            res_sim.final_certificates, [-1.0, -0.9, -0.9, -0.9], atol=1e-6
        )
        assert res_eng.rounds == 10
        # message accounting matches too: 10 broadcasts x 3, 9 adoptions x 3
        assert res_eng.messages_sent == res_sim.messages_sent == 30
        assert res_eng.messages_accepted == res_sim.messages_accepted == 27
        assert res_eng.messages_discarded == res_sim.messages_discarded == 0
        # ring routing: every adopter took worker 0's model
        assert [int(m["adopted_from"]) for m in res_eng.final_models[1:]] == [0, 0, 0]

    def test_multi_sender_certs_converge(self):
        w = 8
        eng = TMSNEngine(
            ToyBatchedWorker([1] * w, [0.01 * (i + 1) for i in range(w)]),
            EngineConfig(n_workers=w, delay_rounds=1, max_rounds=50, fault_spec=""),
        )
        res = eng.run()
        certs = np.asarray(res.final_certificates)
        # fastest-decreasing worker (w-1) leads; everyone is within one
        # broadcast round of the global best
        assert certs.min() == pytest.approx(-0.08 * 50)
        assert certs.max() - certs.min() <= 0.08 * 2 + 1e-6
        assert res.messages_accepted > 0

    def test_link_delays_slow_convergence(self):
        w = 4
        mk = lambda d: TMSNEngine(
            ToyBatchedWorker([1, 10**9, 10**9, 10**9], [0.1] * w),
            EngineConfig(n_workers=w, delay_rounds=d, max_rounds=20, fault_spec=""),
        ).run()
        near = mk(1)
        far = mk(8)
        # same sender progress, but laggier links deliver older certs
        assert near.final_certificates[0] == far.final_certificates[0]
        assert max(far.final_certificates[1:]) > max(near.final_certificates[1:])

    def test_laggard_speed_vector(self):
        """A 0.25-speed worker completes ~1/4 of the segments (credit
        accumulator), mirroring the sim's cost/speed clock."""
        w = 3
        eng = TMSNEngine(
            ToyBatchedWorker([1] * w, [0.1] * w),
            EngineConfig(n_workers=w, speed=[1.0, 1.0, 0.25], max_rounds=40,
                         fault_spec=""),
        )
        res = eng.run()
        certs = np.asarray(res.final_certificates)
        assert certs[0] == pytest.approx(-4.0)
        # the laggard's own path only reached -1.0 but gossip kept it close
        assert certs[2] <= -3.8

    def test_fail_stop_mask(self):
        w = 4
        eng = TMSNEngine(
            ToyBatchedWorker([1, 10**9, 10**9, 10**9], [0.1] * w),
            EngineConfig(n_workers=w, fail_round=[5, 10**6, 10**6, 10**6], max_rounds=30,
                         fault_spec=""),
        )
        res = eng.run()
        # sender died after 5 rounds (4 completed segments + 1 dead round);
        # survivors keep its last delivered certificate, run doesn't stall
        assert res.final_certificates[0] == pytest.approx(-0.5)
        assert max(res.final_certificates[1:]) <= -0.4 + 1e-9
        assert res.rounds == 30

    def test_eps_gates_acceptance_not_broadcast(self):
        w = 3
        eng = TMSNEngine(
            ToyBatchedWorker([1, 10**9, 10**9], [0.01] * w),
            EngineConfig(n_workers=w, eps=0.5, max_rounds=20, fault_spec=""),
        )
        res = eng.run()
        assert res.messages_sent > 0  # broadcasts still go out
        assert res.messages_accepted == 0  # but the gap rejects them all
        assert res.messages_discarded > 0

    def test_quantize_latency(self):
        d = quantize_latency(0.05, 0.02, round_dt=0.01, n_workers=6, seed=0)
        assert d.shape == (6, 6)
        assert d.min() >= 1
        assert 4 <= d.max() <= 8  # (0.05..0.07)/0.01, rounded


# ---------------------------------------------------------------------------
# Dispatch chunking: K rounds per jitted lax.scan call must be a pure
# execution-substrate choice — final certificates, history, and exact
# rounds-to-target identical to the one-dispatch-per-round engine.
# ---------------------------------------------------------------------------


class TestChunkedDispatch:
    def _run(self, rpd, **cfg):
        w = 4
        return TMSNEngine(
            ToyBatchedWorker([1, 2, 10**9, 10**9], [0.1, 0.07, 0.1, 0.1]),
            EngineConfig(n_workers=w, rounds_per_dispatch=rpd, **cfg),
        ).run()

    def test_fixed_rounds_identical(self):
        """max_rounds not divisible by the chunk exercises the
        remainder chunk; certs, history, and counters must match."""
        runs = {rpd: self._run(rpd, max_rounds=21) for rpd in (1, 8, 21, 32)}
        base = runs[1]
        assert base.rounds == 21
        for rpd, res in runs.items():
            assert res.final_certificates == base.final_certificates, rpd
            assert res.history == base.history, rpd
            assert res.rounds == base.rounds, rpd
            assert res.messages_sent == base.messages_sent, rpd
            assert res.messages_accepted == base.messages_accepted, rpd

    def test_target_stop_mid_chunk_identical(self):
        """Crossing the target inside a chunk freezes the device state
        on the crossing round: exact rounds-to-target AND a final state
        identical to the unchunked run."""
        runs = {rpd: self._run(rpd, target_certificate=-0.95, max_rounds=500)
                for rpd in (1, 8)}
        assert runs[1].rounds == runs[8].rounds == 10
        assert runs[8].final_certificates == runs[1].final_certificates
        assert runs[8].history == runs[1].history
        assert runs[8].messages_sent == runs[1].messages_sent

    def test_rejects_bad_chunk(self):
        with pytest.raises(ValueError, match="rounds_per_dispatch"):
            TMSNEngine(
                ToyBatchedWorker([1], [0.1]),
                EngineConfig(n_workers=1, rounds_per_dispatch=0),
            )

    def test_sparrow_chunked_identical(self, small_data):
        """The real batched worker through chunked dispatch: same final
        certificates and history as one dispatch per round."""
        xtr, ytr, _, _ = small_data
        w = 3
        cfg = _cfg(w, sample_size=256, capacity=16,
                   scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25))
        runs = {}
        for rpd in (1, 4):
            eng = TMSNEngine(
                BatchedSparrowWorker(xtr, ytr, cfg),
                EngineConfig(n_workers=w, max_rounds=10, seed=0,
                             rounds_per_dispatch=rpd),
            )
            runs[rpd] = eng.run()
        assert runs[4].final_certificates == runs[1].final_certificates
        assert runs[4].history == runs[1].history
        assert runs[4].messages_sent == runs[1].messages_sent


# ---------------------------------------------------------------------------
# Batched Sparrow vs the unbatched oracle
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def small_data():
    xb, y, _ = make_splice_like(SpliceConfig(n=20_000, d=16, num_bins=8, seed=3))
    return train_test_split(xb, y)


def _cfg(w, **kw):
    base = dict(
        sample_size=1024,
        capacity=64,
        scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
        n_workers=w,
    )
    base.update(kw)
    return SparrowConfig(**base)


class TestBatchedSparrow:
    def test_feature_masks_match_unbatched(self, small_data):
        xtr, ytr, _, _ = small_data
        cfg = _cfg(3, ownership_redundancy=2)
        uw = SparrowWorker(xtr, ytr, cfg)
        masks = feature_ownership_masks(uw.d, 3, 2)
        for i in range(3):
            np.testing.assert_array_equal(masks[i], np.asarray(uw.feature_mask(i)))

    def test_scan_segments_match_unbatched(self, small_data):
        """40 scan segments, 3 workers: certificates, models and sample
        margins must match the per-worker oracle."""
        xtr, ytr, _, _ = small_data
        w = 3
        cfg = _cfg(w, ess_threshold=0.0)  # no resample inside this window
        bw = BatchedSparrowWorker(xtr, ytr, cfg)
        uw = SparrowWorker(xtr, ytr, cfg)
        bstate = bw.init_batch(w, 0)
        ustates = [uw.init_state(i, 1000 * i) for i in range(w)]
        for i in range(w):
            np.testing.assert_array_equal(
                np.asarray(bstate.sample.xb[i]), np.asarray(ustates[i].sample.xb)
            )
        mask = jnp.ones((w,), bool)
        for _ in range(40):
            bstate, _, _ = bw.scan_round(bstate, mask)
            ustates = [uw.run_segment(s)[0] for s in ustates]
        np.testing.assert_allclose(
            np.asarray(bstate.cert),
            np.asarray([s.cert for s in ustates], np.float32),
            rtol=1e-5,
            atol=1e-6,
        )
        for i in range(w):
            assert int(bstate.model.count[i]) == int(ustates[i].model.count)
            np.testing.assert_array_equal(
                np.asarray(bstate.model.feat[i]), np.asarray(ustates[i].model.feat)
            )
            np.testing.assert_allclose(
                np.asarray(bstate.model.alpha[i]),
                np.asarray(ustates[i].model.alpha),
                rtol=1e-5,
            )
            np.testing.assert_allclose(
                np.asarray(bstate.sample.margin_l[i]),
                np.asarray(ustates[i].sample.margin_l),
                rtol=1e-4,
                atol=1e-5,
            )

    @pytest.mark.slow
    def test_resample_path_matches_unbatched(self, small_data):
        """Aggressive ESS threshold forces resamples; the batched redraw
        must be bit-identical (same RNG stream, same systematic sampler)."""
        xtr, ytr, _, _ = small_data
        w = 2
        cfg = _cfg(w, ess_threshold=0.9)
        bw = BatchedSparrowWorker(xtr, ytr, cfg)
        uw = SparrowWorker(xtr, ytr, cfg)
        bstate = bw.init_batch(w, 0)
        ustates = [uw.init_state(i, 1000 * i) for i in range(w)]
        mask = jnp.ones((w,), bool)
        for _ in range(150):
            need = bw.needs_resample(bstate)
            if bool(jnp.any(need)):
                bstate, _ = bw.resample_round(bstate, need)
                bstate, _, _ = bw.scan_round(bstate, mask & ~need)
            else:
                bstate, _, _ = bw.scan_round(bstate, mask)
            ustates = [uw.run_segment(s)[0] for s in ustates]
        assert int(bstate.resamples.sum()) >= 1
        np.testing.assert_array_equal(
            np.asarray(bstate.resamples), [s.resamples for s in ustates]
        )
        np.testing.assert_allclose(
            np.asarray(bstate.cert),
            np.asarray([s.cert for s in ustates], np.float32),
            rtol=1e-4,
            atol=1e-5,
        )
        for i in range(w):
            np.testing.assert_array_equal(
                np.asarray(bstate.sample.xb[i]), np.asarray(ustates[i].sample.xb)
            )

    def test_kernel_scan_path_under_vmap(self, small_data):
        """ScannerConfig.use_kernel routes the batched scan through the
        Pallas edge_scan kernel; histograms and certs must agree with
        the pure-jnp path."""
        xtr, ytr, _, _ = small_data
        states = {}
        for use_kernel in (True, False):
            cfg = _cfg(
                2,
                sample_size=256,
                capacity=16,
                scanner=ScannerConfig(
                    chunk_size=128, num_bins=8, gamma0=0.25, use_kernel=use_kernel
                ),
            )
            b = BatchedSparrowWorker(xtr, ytr, cfg)
            s = b.init_batch(2, 0)
            for _ in range(6):
                s, _, _ = b.scan_round(s, jnp.ones((2,), bool))
            states[use_kernel] = s
        np.testing.assert_allclose(
            np.asarray(states[True].scanner.hist),
            np.asarray(states[False].scanner.hist),
            rtol=1e-4,
            atol=1e-4,
        )
        np.testing.assert_allclose(
            np.asarray(states[True].cert), np.asarray(states[False].cert), rtol=1e-4
        )

    def test_adopt_batch_matches_unbatched(self, small_data):
        """Adoption = prefix-sharing margin transfer; batched vs oracle."""
        xtr, ytr, _, _ = small_data
        w = 2
        cfg = _cfg(w, ess_threshold=0.0)
        bw = BatchedSparrowWorker(xtr, ytr, cfg)
        uw = SparrowWorker(xtr, ytr, cfg)
        bstate = bw.init_batch(w, 0)
        ustates = [uw.init_state(i, 1000 * i) for i in range(w)]
        mask = jnp.ones((w,), bool)
        for _ in range(30):  # let both workers grow different models
            bstate, _, _ = bw.scan_round(bstate, mask)
            ustates = [uw.run_segment(s)[0] for s in ustates]
        assert min(int(c) for c in bstate.model.count) > 0
        # worker 1 adopts worker 0's model in both substrates
        models = bw.export_models(bstate)
        donor = jax.tree_util.tree_map(lambda a: a[jnp.asarray([0, 0])], models)
        take = jnp.asarray([False, True])
        bstate2, cost = bw.adopt_batch(bstate, donor, bstate.cert[jnp.asarray([0, 0])], take)
        u1 = uw.adopt(ustates[1], ustates[0].model, ustates[0].cert)
        assert float(cost[0]) == 0.0 and float(cost[1]) > 0.0
        assert float(bstate2.cert[1]) == pytest.approx(ustates[0].cert, rel=1e-6)
        np.testing.assert_allclose(
            np.asarray(bstate2.sample.margin_l[1]),
            np.asarray(u1.sample.margin_l),
            rtol=1e-4,
            atol=1e-4,
        )
        # worker 0 untouched
        np.testing.assert_array_equal(
            np.asarray(bstate2.model.feat[0]), np.asarray(bstate.model.feat[0])
        )

    def test_common_prefix_len_matches_numpy(self, small_data):
        xtr, ytr, _, _ = small_data
        w = 2
        cfg = _cfg(w, ess_threshold=0.0)
        bw = BatchedSparrowWorker(xtr, ytr, cfg)
        bstate = bw.init_batch(w, 0)
        mask = jnp.ones((w,), bool)
        for _ in range(25):
            bstate, _, _ = bw.scan_round(bstate, mask)
        a = jax.tree_util.tree_map(lambda x: x[0], bstate.model)
        b = jax.tree_util.tree_map(lambda x: x[1], bstate.model)
        ref = SparrowWorker._common_prefix(a, b)
        assert int(common_prefix_len(a, b)) == ref
        assert int(common_prefix_len(a, a)) == int(a.count)


@pytest.mark.slow
class TestEngineSparrowEndToEnd:
    def test_engine_learns_and_gossips(self, small_data):
        xtr, ytr, xte, yte = small_data
        from repro.boosting.stumps import exp_loss

        w = 8
        cfg = _cfg(w, capacity=48, scanner=ScannerConfig(chunk_size=256, num_bins=8))
        worker = BatchedSparrowWorker(xtr, ytr, cfg)
        eng = TMSNEngine(
            worker, EngineConfig(n_workers=w, max_rounds=120, seed=0)
        )
        res = eng.run()
        certs = np.asarray(res.final_certificates)
        assert certs.min() < -0.05
        assert res.messages_sent > 0 and res.messages_accepted > 0
        # gossip keeps the cohort tight
        assert certs.max() - certs.min() < 0.05
        best = int(np.argmin(certs))
        assert float(exp_loss(res.final_models[best], xte, yte)) < 0.95
        # best-cert envelope is monotone by construction
        trace = res.best_certificate_trace()
        vals = [c for _, c in trace]
        assert vals == sorted(vals, reverse=True)

    def test_engine_heterogeneous_run(self, small_data):
        """Laggards + a fail-stop + real link delays in one engine run."""
        xtr, ytr, _, _ = small_data
        w = 8
        cfg = _cfg(w, capacity=48, scanner=ScannerConfig(chunk_size=256, num_bins=8))
        worker = BatchedSparrowWorker(xtr, ytr, cfg)
        speed = np.ones(w)
        speed[-1] = 0.1
        fail = np.full(w, 10**6)
        fail[-2] = 30
        delays = quantize_latency(0.05, 0.02, 0.05, w, seed=1)
        eng = TMSNEngine(
            worker,
            EngineConfig(
                n_workers=w,
                delay_rounds=delays,
                speed=speed,
                fail_round=fail,
                max_rounds=120,
                seed=0,
            ),
        )
        res = eng.run()
        live = [c for i, c in enumerate(res.final_certificates) if i != w - 2]
        assert min(live) < -0.03  # survivors progressed despite failure + laggard
