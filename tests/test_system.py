"""End-to-end behaviour tests for the paper's system: the full TMSN +
Sparrow pipeline against its baselines, and the TMSN-SGD trainer path
used by the production launch layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.boosting import (
    BoosterConfig,
    SparrowConfig,
    SparrowWorker,
    train_exact_greedy,
)
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import exp_loss
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split


# full-pipeline convergence runs — excluded from the fast CI tier
pytestmark = pytest.mark.slow


def _data():
    xb, y, _ = make_splice_like(SpliceConfig(n=24_000, d=24, num_bins=8, seed=11))
    return train_test_split(xb, y)


class TestEndToEnd:
    def test_tmsn_sparrow_beats_trivial_and_tracks_baseline(self):
        """Full pipeline: 3 async workers (one laggard) learn a model
        whose test loss is far below trivial and within 15% of the
        exact-greedy full-scan baseline's at matched boosting effort."""
        xtr, ytr, xte, yte = _data()
        nw = 3
        cfg = SparrowConfig(
            sample_size=2048, capacity=256,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
            n_workers=nw, mem_read_cost=0.25, disk_read_cost=1.0,
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        specs = [WorkerSpec(), WorkerSpec(), WorkerSpec(speed=0.1)]
        sim = TMSNSimulator(
            worker, specs, SimulatorConfig(n_workers=nw, max_events=2500, eps=0.0)
        )
        res = sim.run()
        best = int(np.argmin(res.final_certificates))
        sparrow_loss = float(exp_loss(res.final_models[best], xte, yte))

        base = train_exact_greedy(
            xtr, ytr, BoosterConfig(num_rounds=30, num_bins=8, eval_every=29),
            eval_fn=lambda m: float(exp_loss(m, xte, yte)),
        )
        assert sparrow_loss < 0.9  # way below the trivial 1.0
        assert sparrow_loss < base.metric[-1] * 1.15
        # protocol actually exercised
        assert res.messages_sent > 0 and res.messages_accepted > 0

    def test_certificates_are_sound_across_workers(self):
        """TMSN's correctness contract: every worker's final certificate
        upper-bounds its model's TRAIN potential."""
        xtr, ytr, _, _ = _data()
        cfg = SparrowConfig(
            sample_size=2048, capacity=128,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
            n_workers=2,
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        sim = TMSNSimulator(
            worker, [WorkerSpec(), WorkerSpec()],
            SimulatorConfig(n_workers=2, max_events=800, eps=0.0),
        )
        res = sim.run()
        for model, cert in zip(res.final_models, res.final_certificates):
            potential = float(exp_loss(model, xtr, ytr))
            assert potential <= float(np.exp(cert)) * 1.05, (potential, np.exp(cert))

    def test_parallel_sampler_not_slower(self):
        """Beyond-paper overlap can only reduce blocked time."""
        xtr, ytr, _, _ = _data()
        totals = {}
        for ps in (False, True):
            cfg = SparrowConfig(
                sample_size=2048, capacity=128,
                scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
                mem_read_cost=0.25, disk_read_cost=1.0, parallel_sampler=ps,
            )
            worker = SparrowWorker(xtr, ytr, cfg)
            st = worker.init_state(0, 0)
            cost = 0.0
            for _ in range(400):
                st, c, _ = worker.run_segment(st)
                cost += c
            totals[ps] = cost
        assert totals[True] <= totals[False] + 1e-6


class TestTrainerPath:
    def test_small_lm_loss_descends(self):
        """examples/train_lm.py's model family trains end to end."""
        import dataclasses

        from repro.configs import get_config
        from repro.launch.steps import make_train_step
        from repro.models import init_params
        from repro.optim import AdamWConfig, init_opt_state

        cfg = dataclasses.replace(
            get_config("yi-9b"),
            num_layers=2, d_model=128, num_heads=4, num_kv_heads=2,
            d_ff=256, vocab=512, head_dim=32,
            param_dtype="float32", compute_dtype="float32", remat=False,
        )
        params = init_params(cfg, jax.random.PRNGKey(0))
        opt_cfg = AdamWConfig(lr=3e-3)
        opt = init_opt_state(params, opt_cfg)
        step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
        # learnable stream: tokens follow a fixed cyclic pattern (uniform
        # random tokens have nothing to learn — loss just wanders ~ln V)
        base = jnp.arange(4 * 32, dtype=jnp.int32).reshape(4, 32) * 7 % cfg.vocab
        batch = {
            "tokens": base,
            "labels": jnp.concatenate([base[:, 1:], base[:, :1]], axis=1),
            "mask": jnp.ones((4, 32), jnp.float32),
        }
        losses = []
        for i in range(25):
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0] * 0.8, losses[:: max(len(losses) // 6, 1)]
