"""`EngineConfig` knob validation and the `REPRO_*` env-override layer.

The env overrides only supply DEFAULTS: an explicit `EngineConfig`
argument always wins (this is what lets equivalence tests pin their
knobs while the CI matrix legs steer every env-following run). Unset,
empty, and whitespace-only variables fall back to the built-in default;
malformed values raise naming the variable. All of this is documented
in docs/config.md — tests/test_docs.py guards the doc side.

These tests run on any device count (no mesh needed), so they sit in
tier-1 everywhere.
"""

import dataclasses

import pytest

from repro.core.engine import EngineConfig, TMSNEngine, make_engine

INT_KNOBS = [
    ("REPRO_ROUNDS_PER_DISPATCH", "rounds_per_dispatch", 8),
    ("REPRO_CROSS_POD_EVERY_K", "cross_pod_every_k", 1),
    ("REPRO_CROSS_POD_TOP_K", "cross_pod_top_k", 1),
    ("REPRO_INFLIGHT_CAPACITY", "inflight_capacity", 0),
    ("REPRO_SPARE_SLOTS", "spare_slots", 0),
    ("REPRO_PUBLISH_EVERY_K", "publish_every_k", 0),
]

ALL_VARS = [v for v, _, _ in INT_KNOBS] + [
    "REPRO_GOSSIP_MODE",
    "REPRO_ROUND_STEP_IMPL",
    "REPRO_CONTROL_PLANE",
    "REPRO_FAULT_PLAN",
    "REPRO_PUBLISH_EPS",
]


@pytest.fixture(autouse=True)
def _clean_env(monkeypatch):
    """Each test starts from an unset REPRO_* environment (the dev's
    shell or a CI matrix leg must not leak into assertions)."""
    for var in ALL_VARS:
        monkeypatch.delenv(var, raising=False)


class TestIntOverrides:
    @pytest.mark.parametrize("var,field,default", INT_KNOBS)
    def test_unset_uses_builtin_default(self, var, field, default):
        assert getattr(EngineConfig(), field) == default

    @pytest.mark.parametrize("var,field,default", INT_KNOBS)
    def test_env_value_becomes_default(self, var, field, default, monkeypatch):
        monkeypatch.setenv(var, "3")
        assert getattr(EngineConfig(), field) == 3

    @pytest.mark.parametrize("var,field,default", INT_KNOBS)
    @pytest.mark.parametrize("raw", ["", "   ", "\t"])
    def test_empty_or_whitespace_falls_back(self, var, field, default, raw, monkeypatch):
        monkeypatch.setenv(var, raw)
        assert getattr(EngineConfig(), field) == default

    @pytest.mark.parametrize("var,field,default", INT_KNOBS)
    @pytest.mark.parametrize("raw", ["four", "4.5", "4x", "0x4"])
    def test_malformed_value_raises_naming_the_var(self, var, field, default, raw, monkeypatch):
        monkeypatch.setenv(var, raw)
        with pytest.raises(ValueError, match=var):
            EngineConfig()

    @pytest.mark.parametrize("var,field,default", INT_KNOBS)
    def test_explicit_arg_beats_env(self, var, field, default, monkeypatch):
        monkeypatch.setenv(var, "7")
        assert getattr(EngineConfig(**{field: 5}), field) == 5

    def test_padded_int_is_accepted(self, monkeypatch):
        monkeypatch.setenv("REPRO_ROUNDS_PER_DISPATCH", " 16 ")
        assert EngineConfig().rounds_per_dispatch == 16


class TestGossipModeOverride:
    def test_unset_defaults_dense(self):
        assert EngineConfig().gossip_mode == "dense"

    def test_env_value_becomes_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_GOSSIP_MODE", "gated")
        assert EngineConfig().gossip_mode == "gated"

    def test_empty_falls_back(self, monkeypatch):
        monkeypatch.setenv("REPRO_GOSSIP_MODE", "  ")
        assert EngineConfig().gossip_mode == "dense"

    def test_explicit_arg_beats_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_GOSSIP_MODE", "gated")
        assert EngineConfig(gossip_mode="dense").gossip_mode == "dense"

    def test_invalid_env_mode_rejected_at_engine_construction(self, monkeypatch):
        """Mode VALIDATION lives with the engine, not the env parser —
        an unknown mode is rejected identically whether it came from
        the env or an explicit argument."""
        monkeypatch.setenv("REPRO_GOSSIP_MODE", "sparse")
        cfg = EngineConfig(n_workers=2)
        assert cfg.gossip_mode == "sparse"  # parsing is permissive ...
        with pytest.raises(ValueError, match="gossip_mode"):
            make_engine(_StubWorker(), cfg)  # ... construction is not


class TestRoundStepImplOverride:
    def test_unset_defaults_pallas(self):
        assert EngineConfig().round_step_impl == "pallas"

    def test_env_value_becomes_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_ROUND_STEP_IMPL", "ref")
        assert EngineConfig().round_step_impl == "ref"

    def test_empty_falls_back(self, monkeypatch):
        monkeypatch.setenv("REPRO_ROUND_STEP_IMPL", "  ")
        assert EngineConfig().round_step_impl == "pallas"

    def test_explicit_arg_beats_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_ROUND_STEP_IMPL", "ref")
        assert EngineConfig(round_step_impl="pallas").round_step_impl == "pallas"

    def test_invalid_impl_rejected_at_engine_construction(self, monkeypatch):
        monkeypatch.setenv("REPRO_ROUND_STEP_IMPL", "mosaic")
        with pytest.raises(ValueError, match="round_step_impl"):
            make_engine(_StubWorker(), EngineConfig(n_workers=2))


class TestControlPlaneOverride:
    def test_unset_defaults_dense(self):
        assert EngineConfig().control_plane == "dense"

    def test_env_value_becomes_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_CONTROL_PLANE", "sparse")
        assert EngineConfig().control_plane == "sparse"

    def test_empty_falls_back(self, monkeypatch):
        monkeypatch.setenv("REPRO_CONTROL_PLANE", "  ")
        assert EngineConfig().control_plane == "dense"

    def test_explicit_arg_beats_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_CONTROL_PLANE", "sparse")
        assert EngineConfig(control_plane="dense").control_plane == "dense"

    def test_invalid_plane_rejected_at_engine_construction(self, monkeypatch):
        monkeypatch.setenv("REPRO_CONTROL_PLANE", "topk")
        cfg = EngineConfig(n_workers=2)
        assert cfg.control_plane == "topk"  # parsing is permissive ...
        with pytest.raises(ValueError, match="control_plane"):
            make_engine(_StubWorker(), cfg)  # ... construction is not


class TestAutoCapacityKnob:
    """`inflight_capacity` is an int knob with one special string value:
    "auto" (case-insensitive via the env layer) defers sizing to the
    warm-up occupancy probe."""

    @pytest.mark.parametrize("raw", ["auto", "AUTO", " Auto "])
    def test_env_auto_becomes_default(self, raw, monkeypatch):
        monkeypatch.setenv("REPRO_INFLIGHT_CAPACITY", raw)
        assert EngineConfig().inflight_capacity == "auto"

    def test_explicit_auto_constructs(self):
        TMSNEngine(_StubWorker(), EngineConfig(n_workers=2, inflight_capacity="auto"))

    def test_explicit_int_beats_env_auto(self, monkeypatch):
        monkeypatch.setenv("REPRO_INFLIGHT_CAPACITY", "auto")
        assert EngineConfig(inflight_capacity=4).inflight_capacity == 4

    def test_malformed_near_auto_still_raises(self, monkeypatch):
        """"auto" is the ONLY special value — anything else non-integer
        stays a malformed-int error naming the variable."""
        monkeypatch.setenv("REPRO_INFLIGHT_CAPACITY", "autox")
        with pytest.raises(ValueError, match="REPRO_INFLIGHT_CAPACITY"):
            EngineConfig()

    def test_other_strings_rejected_at_engine_construction(self):
        with pytest.raises(ValueError, match="inflight_capacity"):
            TMSNEngine(
                _StubWorker(), EngineConfig(n_workers=2, inflight_capacity="big")
            )


class TestFaultPlanOverride:
    """REPRO_FAULT_PLAN is a STRING knob holding a structured spec
    ("drop=5,corrupt=3,seed=9,part=8:16" — integer percents). Like the
    mode knobs, the env layer is permissive and the spec is parsed —
    with errors naming the variable — at engine construction."""

    def test_unset_defaults_empty(self):
        assert EngineConfig().fault_spec == ""

    def test_env_value_becomes_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULT_PLAN", "drop=5,seed=9")
        assert EngineConfig().fault_spec == "drop=5,seed=9"

    def test_empty_falls_back(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULT_PLAN", "  ")
        assert EngineConfig().fault_spec == ""

    def test_explicit_arg_beats_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_FAULT_PLAN", "drop=50")
        assert EngineConfig(fault_spec="").fault_spec == ""

    @pytest.mark.parametrize(
        "raw", ["drop=x", "drop", "bogus=1", "drop=101", "dup=-1", "part=5"]
    )
    def test_malformed_spec_raises_naming_the_var(self, raw, monkeypatch):
        monkeypatch.setenv("REPRO_FAULT_PLAN", raw)
        cfg = EngineConfig(n_workers=2)
        assert cfg.fault_spec == raw  # parsing is permissive ...
        with pytest.raises(ValueError, match="REPRO_FAULT_PLAN"):
            make_engine(_StubWorker(), cfg)  # ... construction is not

    def test_explicit_plan_beats_spec(self, monkeypatch):
        """A programmatic FaultPlan wins over the env spec string — the
        same explicit-beats-env rule every other knob follows."""
        from repro.core.engine import FaultPlan

        monkeypatch.setenv("REPRO_FAULT_PLAN", "drop=x")  # would not parse
        eng = make_engine(
            _StubWorker(),
            EngineConfig(n_workers=2, fault_plan=FaultPlan(drop_prob=0.1, seed=1)),
        )
        assert eng._fault is not None and eng._fault.drop_prob == 0.1

    def test_all_zero_spec_is_a_clean_run(self):
        eng = make_engine(
            _StubWorker(), EngineConfig(n_workers=2, fault_spec="drop=0,seed=7")
        )
        assert eng._fault is None


class TestPublishKnobs:
    """The serving-edge knobs: `publish_every_k` rides the shared int
    parametrization above; `publish_eps` is the first FLOAT knob
    (`_env_float`, same unset/empty/malformed contract)."""

    def test_eps_unset_defaults_zero(self):
        assert EngineConfig().publish_eps == 0.0

    def test_eps_env_value_becomes_default(self, monkeypatch):
        monkeypatch.setenv("REPRO_PUBLISH_EPS", "0.25")
        assert EngineConfig().publish_eps == 0.25

    @pytest.mark.parametrize("raw", ["", "   ", "\t"])
    def test_eps_empty_or_whitespace_falls_back(self, raw, monkeypatch):
        monkeypatch.setenv("REPRO_PUBLISH_EPS", raw)
        assert EngineConfig().publish_eps == 0.0

    @pytest.mark.parametrize("raw", ["x", "1..5", "0.1f", "1,5"])
    def test_eps_malformed_raises_naming_the_var(self, raw, monkeypatch):
        monkeypatch.setenv("REPRO_PUBLISH_EPS", raw)
        with pytest.raises(ValueError, match="REPRO_PUBLISH_EPS"):
            EngineConfig()

    def test_eps_explicit_arg_beats_env(self, monkeypatch):
        monkeypatch.setenv("REPRO_PUBLISH_EPS", "0.5")
        assert EngineConfig(publish_eps=0.125).publish_eps == 0.125

    def test_eps_scientific_notation_accepted(self, monkeypatch):
        monkeypatch.setenv("REPRO_PUBLISH_EPS", " 1e-3 ")
        assert EngineConfig().publish_eps == 1e-3

    def test_negative_eps_rejected_at_engine_construction(self):
        with pytest.raises(ValueError, match="publish_eps"):
            TMSNEngine(_StubWorker(), EngineConfig(n_workers=2, publish_eps=-0.1))

    def test_nan_eps_rejected_at_engine_construction(self):
        with pytest.raises(ValueError, match="publish_eps"):
            TMSNEngine(
                _StubWorker(), EngineConfig(n_workers=2, publish_eps=float("nan"))
            )

    def test_every_k_zero_disables_and_negative_rejected(self):
        TMSNEngine(_StubWorker(), EngineConfig(n_workers=2, publish_every_k=0))
        with pytest.raises(ValueError, match="publish_every_k"):
            TMSNEngine(_StubWorker(), EngineConfig(n_workers=2, publish_every_k=-1))

    def test_attach_publisher_requires_cadence(self):
        """A publisher on a publish_every_k=0 engine would silently
        never fire — reject the attach instead."""
        from repro.launch.serving import AdoptionSlot

        eng = TMSNEngine(_StubWorker(), EngineConfig(n_workers=2))
        with pytest.raises(ValueError, match="publish_every_k"):
            eng.attach_publisher(AdoptionSlot())


class TestSpareSlotsKnob:
    def test_env_out_of_range_rejected_at_engine_construction(self, monkeypatch):
        monkeypatch.setenv("REPRO_SPARE_SLOTS", "2")
        with pytest.raises(ValueError, match="spare_slots"):
            make_engine(_StubWorker(), EngineConfig(n_workers=2))

    def test_negative_rejected(self):
        with pytest.raises(ValueError, match="spare_slots"):
            make_engine(_StubWorker(), EngineConfig(n_workers=2, spare_slots=-1))


class TestKnobValidation:
    """Range checks fire at engine construction for env and explicit
    values alike."""

    @pytest.mark.parametrize(
        "field", ["rounds_per_dispatch", "cross_pod_every_k", "cross_pod_top_k", "gossip_top_k"]
    )
    def test_non_positive_rejected(self, field):
        with pytest.raises(ValueError, match=field):
            TMSNEngine(_StubWorker(), EngineConfig(n_workers=2, **{field: 0}))

    def test_env_supplied_zero_also_rejected(self, monkeypatch):
        monkeypatch.setenv("REPRO_CROSS_POD_EVERY_K", "0")
        with pytest.raises(ValueError, match="cross_pod_every_k"):
            TMSNEngine(_StubWorker(), EngineConfig(n_workers=2))

    def test_inflight_capacity_zero_is_the_dense_oracle(self):
        """Unlike the other int knobs, 0 is VALID here (dense mode);
        only negatives are rejected."""
        TMSNEngine(_StubWorker(), EngineConfig(n_workers=2, inflight_capacity=0))
        with pytest.raises(ValueError, match="inflight_capacity"):
            TMSNEngine(_StubWorker(), EngineConfig(n_workers=2, inflight_capacity=-1))


def test_every_env_knob_is_a_config_field():
    """The override surface stays in lockstep with the dataclass: every
    REPRO_-overridable knob tested here must still be an EngineConfig
    field (renames must update the env layer and these tests)."""
    fields = {f.name for f in dataclasses.fields(EngineConfig)}
    for _, field, _ in INT_KNOBS:
        assert field in fields
    assert "gossip_mode" in fields
    assert "control_plane" in fields
    assert "fault_spec" in fields
    assert "fault_plan" in fields
    assert "membership" in fields
    assert "publish_eps" in fields


class _StubWorker:
    """Never run — just enough surface for TMSNEngine.__init__ (which
    validates config before touching the worker)."""

    def payload_bytes(self):
        return 8
