"""Tests for the Sparrow boosting substrate: stumps, histogram edges,
sampler, scanner, and the single-worker loop."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.boosting import (
    BoosterConfig,
    SparrowConfig,
    SparrowWorker,
    train_exact_greedy,
    train_goss,
)
from repro.boosting.sampler import inclusion_counts, minimal_variance_sample, rejection_sample
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import (
    alpha_from_gamma,
    append_stump,
    bin_features,
    best_stump_exact,
    edge_histogram,
    edges_from_histogram,
    empty_model,
    error_rate,
    exp_loss,
    predict_margin,
    predict_margin_delta,
)
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover
    HAVE_HYPOTHESIS = False


@pytest.fixture(scope="module")
def small_data():
    xb, y, _ = make_splice_like(SpliceConfig(n=20_000, d=16, num_bins=8, seed=3))
    return train_test_split(xb, y)


class TestStumps:
    def test_empty_model_margin_zero(self):
        m = empty_model(8)
        xb = jnp.zeros((5, 3), jnp.int32)
        assert jnp.all(predict_margin(m, xb) == 0.0)

    def test_append_and_margin(self):
        m = empty_model(8)
        m = append_stump(m, 1, 2, 1.0, 0.5)
        xb = jnp.array([[0, 3, 0], [0, 1, 0]], jnp.int32)
        mg = predict_margin(m, xb)
        np.testing.assert_allclose(np.asarray(mg), [0.5, -0.5])

    def test_capacity_is_respected(self):
        m = empty_model(2)
        for k in range(5):
            m = append_stump(m, k % 3, 0, 1.0, 1.0)
        assert int(m.count) == 2

    def test_margin_delta_matches_full(self):
        key = jax.random.PRNGKey(0)
        xb = jax.random.randint(key, (50, 6), 0, 8, dtype=jnp.int32)
        m = empty_model(16)
        mid_margin = None
        for k in range(10):
            m = append_stump(m, k % 6, k % 7, (-1.0) ** k, 0.1 * (k + 1))
            if k == 4:
                mid_margin = predict_margin(m, xb)
        full = predict_margin(m, xb)
        t_from = jnp.full((50,), 5, jnp.int32)
        delta = predict_margin_delta(m, xb, t_from)
        np.testing.assert_allclose(np.asarray(mid_margin + delta), np.asarray(full), rtol=1e-5)

    def test_edge_histogram_matches_bruteforce(self):
        key = jax.random.PRNGKey(1)
        k1, k2 = jax.random.split(key)
        xb = jax.random.randint(k1, (200, 5), 0, 6, dtype=jnp.int32)
        wy = jax.random.normal(k2, (200,))
        hist = edge_histogram(xb, wy, 6)
        ref = np.zeros((5, 6), np.float32)
        for i in range(200):
            for j in range(5):
                ref[j, int(xb[i, j])] += float(wy[i])
        np.testing.assert_allclose(np.asarray(hist), ref, rtol=1e-4, atol=1e-4)

    def test_edges_match_bruteforce(self):
        key = jax.random.PRNGKey(2)
        k1, k2, k3 = jax.random.split(key, 3)
        xb = jax.random.randint(k1, (300, 4), 0, 5, dtype=jnp.int32)
        y = jnp.where(jax.random.bernoulli(k2, 0.5, (300,)), 1.0, -1.0)
        w = jax.random.uniform(k3, (300,)) + 0.1
        edges = edges_from_histogram(edge_histogram(xb, w * y, 5))
        for j in range(4):
            for t in range(4):
                h = jnp.where(xb[:, j] > t, 1.0, -1.0)
                ref = float(jnp.sum(w * y * h))
                assert float(edges[j, t]) == pytest.approx(ref, rel=1e-3, abs=1e-3)

    def test_best_stump_exact_recovers_planted_rule(self):
        key = jax.random.PRNGKey(4)
        xb = jax.random.randint(key, (5000, 10), 0, 8, dtype=jnp.int32)
        y = jnp.where(xb[:, 7] > 3, 1.0, -1.0)  # planted: feature 7, thr 3
        w = jnp.ones(5000)
        feat, thr, sign, gamma = best_stump_exact(xb, y, w, 8)
        assert int(feat) == 7 and int(thr) == 3 and float(sign) == 1.0
        assert float(gamma) == pytest.approx(0.5, abs=1e-5)

    def test_alpha_from_gamma(self):
        assert float(alpha_from_gamma(0.0)) == pytest.approx(0.0)
        # err = 0.25 -> alpha = 0.5 log(3)
        assert float(alpha_from_gamma(0.25)) == pytest.approx(0.5 * np.log(3.0), rel=1e-5)

    def test_bin_features_monotone(self):
        x = jnp.linspace(-1, 1, 100)[:, None]
        bins, cuts = bin_features(x, 4)
        b = np.asarray(bins[:, 0])
        assert (np.diff(b) >= 0).all() and b.min() == 0 and b.max() == 3


class TestSampler:
    def test_minimal_variance_counts(self):
        """Inclusion counts must be floor/ceil of the expectation."""
        key = jax.random.PRNGKey(0)
        w = jnp.asarray([4.0, 2.0, 1.0, 1.0])
        m = 8
        idx = minimal_variance_sample(key, w, m)
        counts = np.asarray(inclusion_counts(idx, 4))
        expect = np.asarray(w) / 8.0 * m
        assert (counts >= np.floor(expect)).all()
        assert (counts <= np.ceil(expect)).all()

    def test_zero_weights_never_selected(self):
        key = jax.random.PRNGKey(1)
        w = jnp.asarray([1.0, 0.0, 1.0, 0.0])
        idx = np.asarray(minimal_variance_sample(key, w, 100))
        assert set(idx.tolist()) <= {0, 2}

    def test_uniform_fallback_on_all_zero(self):
        key = jax.random.PRNGKey(2)
        idx = np.asarray(minimal_variance_sample(key, jnp.zeros(10), 20))
        assert (idx >= 0).all() and (idx <= 9).all()

    def test_rejection_sample_unbiased(self):
        key = jax.random.PRNGKey(3)
        w = jnp.asarray([3.0, 1.0])
        idx = np.asarray(rejection_sample(key, w, 4000))
        frac0 = (idx == 0).mean()
        assert frac0 == pytest.approx(0.75, abs=0.03)

    if HAVE_HYPOTHESIS:

        @settings(deadline=None, max_examples=25)
        @given(
            st.lists(st.floats(min_value=0.0, max_value=100.0), min_size=2, max_size=32),
            st.integers(min_value=1, max_value=64),
        )
        def test_mvs_count_property(self, ws, m):
            """Minimal-variance property: count_i in {floor, ceil}(m*p_i)."""
            w = jnp.asarray(ws, jnp.float32)
            if float(jnp.sum(w)) <= 0:
                return
            idx = minimal_variance_sample(jax.random.PRNGKey(0), w, m)
            counts = np.asarray(inclusion_counts(idx, len(ws)))
            p = np.asarray(w) / float(jnp.sum(w))
            expect = p * m
            assert (counts >= np.floor(expect) - 1e-6).all()
            assert (counts <= np.ceil(expect) + 1e-6).all()


class TestBaselines:
    def test_exact_greedy_drives_loss_down(self, small_data):
        xtr, ytr, xte, yte = small_data
        tr = train_exact_greedy(
            xtr, ytr, BoosterConfig(num_rounds=20, num_bins=8, eval_every=19),
            eval_fn=lambda m: float(exp_loss(m, xte, yte)),
        )
        assert tr.metric[-1] < 0.8  # well below the trivial 1.0

    def test_goss_drives_loss_down(self, small_data):
        xtr, ytr, xte, yte = small_data
        tr = train_goss(
            xtr, ytr, BoosterConfig(num_rounds=20, num_bins=8, eval_every=19),
            eval_fn=lambda m: float(exp_loss(m, xte, yte)),
        )
        assert tr.metric[-1] < 0.85

    def test_goss_costs_less_per_round(self, small_data):
        xtr, ytr, xte, yte = small_data
        cfg = BoosterConfig(num_rounds=10, num_bins=8, eval_every=9)
        a = train_exact_greedy(xtr, ytr, cfg, eval_fn=lambda m: 0.0)
        b = train_goss(xtr, ytr, cfg, eval_fn=lambda m: 0.0)
        assert b.cost[-1] < a.cost[-1]

    def test_boosting_separable_reaches_zero_error(self):
        """AdaBoost oracle property: on separable data driven by a single
        stump, training error hits 0 fast."""
        key = jax.random.PRNGKey(9)
        xb = jax.random.randint(key, (2000, 4), 0, 8, dtype=jnp.int32)
        y = jnp.where(xb[:, 2] > 4, 1.0, -1.0)
        tr = train_exact_greedy(xb, y, BoosterConfig(num_rounds=3, num_bins=8, eval_every=2))
        assert float(error_rate(tr.model, xb, y)) == 0.0


class TestSparrowWorker:
    def test_single_worker_learns(self, small_data):
        xtr, ytr, xte, yte = small_data
        cfg = SparrowConfig(
            sample_size=2048, capacity=64,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
            n_workers=1,
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        sim = TMSNSimulator(
            worker, [WorkerSpec()], SimulatorConfig(n_workers=1, max_events=600)
        )
        res = sim.run()
        model = res.final_models[0]
        assert int(model.count) > 5
        assert float(exp_loss(model, xte, yte)) < 0.9
        # certificate is monotone within the worker
        certs = [c for _, _, c in res.history]
        assert all(b <= a + 1e-9 for a, b in zip(certs, certs[1:]))

    def test_certificate_is_sound_upper_bound(self, small_data):
        """exp(cert) must upper-bound the TRAIN potential w.h.p. — the
        heart of TMSN: certificates must be sound."""
        xtr, ytr, xte, yte = small_data
        cfg = SparrowConfig(
            sample_size=2048, capacity=64,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        sim = TMSNSimulator(worker, [WorkerSpec()], SimulatorConfig(n_workers=1, max_events=400))
        res = sim.run()
        model = res.final_models[0]
        train_potential = float(exp_loss(model, xtr, ytr))
        assert train_potential <= float(np.exp(res.final_certificates[0])) * 1.05

    def test_resampling_triggers(self, small_data):
        xtr, ytr, _, _ = small_data
        cfg = SparrowConfig(
            sample_size=1024, capacity=64,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
            ess_threshold=0.5,  # aggressive -> must resample
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        state = worker.init_state(0, 0)
        resamples = 0
        for _ in range(300):
            state, _, _ = worker.run_segment(state)
            resamples = state.resamples
        assert resamples >= 1

    def test_feature_partition_covers_all(self):
        xb = jnp.zeros((100, 10), jnp.int32)
        y = jnp.ones((100,))
        cfg = SparrowConfig(sample_size=64, n_workers=3)
        w = SparrowWorker(xb, y, cfg)
        masks = np.stack([np.asarray(w.feature_mask(i)) for i in range(3)])
        assert (masks.sum(axis=0) == 1).all()  # disjoint cover


@pytest.mark.slow
class TestTMSNMultiWorker:
    def test_workers_converge_to_same_certificate(self, small_data):
        xtr, ytr, _, _ = small_data
        nw = 3
        cfg = SparrowConfig(
            sample_size=1024, capacity=64,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
            n_workers=nw,
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        sim = TMSNSimulator(
            worker,
            [WorkerSpec() for _ in range(nw)],
            SimulatorConfig(n_workers=nw, max_events=900),
        )
        res = sim.run()
        assert res.messages_sent > 0 and res.messages_accepted > 0
        certs = res.final_certificates
        assert max(certs) - min(certs) < 0.05  # all near-identical

    def test_laggard_does_not_stall(self, small_data):
        """A 100x slower worker must not prevent the fast workers from
        making progress (the paper's resilience claim)."""
        xtr, ytr, _, _ = small_data
        nw = 3
        cfg = SparrowConfig(
            sample_size=1024, capacity=64,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
            n_workers=nw,
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        specs = [WorkerSpec(speed=1.0), WorkerSpec(speed=1.0), WorkerSpec(speed=0.01)]
        sim = TMSNSimulator(
            worker, specs, SimulatorConfig(n_workers=nw, max_events=900)
        )
        res = sim.run()
        fast_certs = [res.final_certificates[0], res.final_certificates[1]]
        assert min(fast_certs) < -0.01  # fast workers progressed

    def test_failed_worker_does_not_poison(self, small_data):
        xtr, ytr, _, _ = small_data
        nw = 3
        cfg = SparrowConfig(
            sample_size=1024, capacity=64,
            scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
            n_workers=nw,
        )
        worker = SparrowWorker(xtr, ytr, cfg)
        specs = [WorkerSpec(), WorkerSpec(), WorkerSpec(fail_at=1000.0)]
        sim = TMSNSimulator(worker, specs, SimulatorConfig(n_workers=nw, max_events=900))
        res = sim.run()
        assert min(res.final_certificates[:2]) < -0.01
