"""Elastic membership + adversarial fault injection, classified exact-vs-measured.

The chaos layer (``FaultPlan`` / ``MembershipPlan`` / ``spare_slots``)
gets the same treatment as every other knob in this repo: each scenario
is either EXACT — pinned bit-for-bit here — or an explicit measured
approximation (bench_scaling.py's chaos section). The exact claims:

  * **join @ k=1 == masked-from-start**: activating a spare at round 1
    is bit-identical to a plain run where that worker was a member all
    along (``workers_joined == 0`` — it never "joined" mid-run);
  * **cross-substrate determinism under faults**: fault masks come from
    a counter-based per-edge hash of ``(round, dst gid, src gid, seed,
    salt)`` — stateless and elementwise, so a faulted run is
    bit-identical on the single-device engine, sharded dense/gated
    gossip, the sparse in-flight queue, the sparse control plane
    (``gossip_top_k=W`` so candidate sets match dense control), and the
    pod mesh;
  * **duplication == clean** under uniform delay and adequate capacity:
    a duplicate is an identical (cert, src, due, slot) queue entry —
    argmin ties on it, round delivery clears both copies. The dense
    buffer absorbs duplicates by construction (one slot per edge);
  * **corruption never poisons**: every corrupted certificate is
    rejected by the eps-gate soundness check (non-finite, or >= the
    destination's current certificate — which monotonicity makes
    forever unacceptable), counted in ``messages_corrupt_rejected``,
    and the best (minimum) final certificate matches the clean run —
    corruption mangles in-flight copies, never local state. Per-worker
    certificates MAY diverge from clean (a corrupted legitimate
    improvement is lost with the message): that part is measured.

Drop/reorder/partition and mid-run churn change delivery and are
measured, but remain exactly reproducible (same plan -> same run) and
deadlock-free — pinned here as completion + counter accounting.
"""

import numpy as np
import pytest

from repro.core.engine import (
    EngineConfig,
    FaultPlan,
    MembershipPlan,
    _parse_fault_spec,
    make_engine,
)
from repro.core.engine_sharded import sharded_engine_available
from test_sharded_engine import ShardableToyWorker, _mesh_for, _pod_mesh_or_skip

W = 8
PERIOD = [1, 2, 3, 1, 2, 3, 1, 2]
DEC = [0.5, 0.9, 1.3, 0.7, 1.1, 0.6, 0.8, 1.0]
ROUNDS = 24

needs_devices = pytest.mark.skipif(
    not sharded_engine_available(),
    reason="sharded chaos substrates need >= 2 devices (XLA_FLAGS forces 8 in CI)",
)

#: the five CI substrates every exact claim is pinned on. Each entry is
#: (tag, needs_mesh, config overrides); "pod" resolves its mesh lazily
#: (it skips on odd device counts).
SUBSTRATES = [
    ("single-dense", False, dict(inflight_capacity=0)),
    ("sharded-dense", True, dict(inflight_capacity=0)),
    ("sharded-gated", True, dict(inflight_capacity=0, gossip_mode="gated")),
    ("sparse-inflight", True, dict(inflight_capacity=16)),
    (
        "sparse-control",
        True,
        dict(
            inflight_capacity=16,
            gossip_mode="gated",
            control_plane="sparse",
            gossip_top_k=W,
        ),
    ),
    ("pod-mesh", "pod", dict(inflight_capacity=0)),
]

SUBSTRATE_IDS = [s[0] for s in SUBSTRATES]


def _toy():
    return ShardableToyWorker(PERIOD, DEC)


def _mesh_or_skip(needs_mesh):
    if needs_mesh == "pod":
        return _pod_mesh_or_skip(pods=2)
    if needs_mesh:
        if not sharded_engine_available():
            pytest.skip("needs >= 2 devices")
        return _mesh_for(W)
    return None


def _run(needs_mesh=False, **kw):
    # pin every env-read knob: these are cross-config identity tests, so
    # no CI matrix leg may flip one side of a comparison (the substrate
    # overrides in SUBSTRATES re-raise exactly what each leg varies)
    kw.setdefault("gossip_mode", "dense")
    kw.setdefault("control_plane", "dense")
    kw.setdefault("rounds_per_dispatch", 8)
    kw.setdefault("cross_pod_every_k", 1)
    kw.setdefault("cross_pod_top_k", 1)
    kw.setdefault("spare_slots", 0)
    cfg = EngineConfig(
        n_workers=W,
        max_rounds=kw.pop("max_rounds", ROUNDS),
        delay_rounds=kw.pop("delay_rounds", 1),
        seed=0,
        fault_spec=kw.pop("fault_spec", ""),
        mesh=_mesh_or_skip(needs_mesh),
        **kw,
    )
    return make_engine(_toy(), cfg).run()


def _same_run(a, b, tag=""):
    """Bit-identical protocol outcome: certificates AND event history."""
    assert a.final_certificates == b.final_certificates, tag
    assert a.history == b.history, tag
    assert a.rounds == b.rounds, tag


def _monotone_history(res):
    """Per-worker certificates never increase along the history."""
    last: dict = {}
    for _, wid, cert in res.history:
        assert np.isfinite(cert), f"non-finite cert for worker {wid}"
        assert cert <= last.get(wid, np.inf), f"cert rose for worker {wid}"
        last[wid] = cert


DROP = FaultPlan(drop_prob=0.3, seed=7)
CORRUPT = FaultPlan(corrupt_prob=0.5, seed=3)
DUP = FaultPlan(duplicate_prob=0.5, seed=5)


class TestMembershipExact:
    """Join-equivalence: the provable membership claims."""

    @pytest.mark.parametrize("tag,needs_mesh,kw", SUBSTRATES, ids=SUBSTRATE_IDS)
    def test_join_at_round_one_is_masked_from_start(self, tag, needs_mesh, kw):
        """Activating a spare at k=1 == plain run with it live from round
        0 — bit-for-bit, and it does not count as a mid-run join."""
        plain = _run(needs_mesh, **kw)
        joined = _run(
            needs_mesh,
            spare_slots=1,
            membership=MembershipPlan(joins=((1, W - 1),)),
            **kw,
        )
        _same_run(joined, plain, tag)
        assert joined.workers_joined == 0

    def test_mid_run_join_counts_and_participates(self):
        res = _run(
            spare_slots=2,
            membership=MembershipPlan(joins=((6, 6), (10, 7))),
        )
        assert res.workers_joined == 2
        assert all(np.isfinite(res.final_certificates))
        # The joiners caught up: adopted/improved past their init cert.
        joiner_events = [e for e in res.history if e[1] in (6, 7)]
        assert joiner_events, "joined spares never improved or adopted"
        _monotone_history(res)

    def test_spare_is_inert_until_join(self):
        """An unactivated spare == a worker fail-stopped at round 0:
        it never sends, adopts, or appears in history — bit-for-bit."""
        fail = np.full(W, ROUNDS + 1, dtype=np.int64)
        fail[W - 2 :] = 0
        masked = _run(fail_round=fail)
        spared = _run(spare_slots=2)
        _same_run(spared, masked, "idle spares vs fail-stop@0")
        # spares contribute only the t=0 initial-certificate record
        assert all(e[1] < W - 2 for e in spared.history if e[0] > 0)
        assert spared.workers_joined == 0

    def test_join_and_leave_compose_into_churn(self):
        res = _run(
            spare_slots=2,
            membership=MembershipPlan(joins=((6, 6), (12, 7)), leaves=((8, 0), (14, 6))),
        )
        assert res.workers_joined == 2
        assert res.rounds == ROUNDS
        _monotone_history(res)

    @needs_devices
    def test_churn_identical_on_sharded_queue_path(self):
        membership = MembershipPlan(joins=((6, 6), (10, 7)), leaves=((12, 1),))
        single = _run(spare_slots=2, membership=membership, inflight_capacity=16)
        sharded = _run(
            True, spare_slots=2, membership=membership, inflight_capacity=16
        )
        _same_run(sharded, single, "churn sharded vs single")
        assert sharded.workers_joined == single.workers_joined == 2


class TestMembershipValidation:
    def _cfg(self, **kw):
        return EngineConfig(
            n_workers=W, max_rounds=4, seed=0, fault_spec="", **kw
        )

    def test_spare_slots_bounds(self):
        with pytest.raises(ValueError, match="spare_slots"):
            make_engine(_toy(), self._cfg(spare_slots=-1))
        with pytest.raises(ValueError, match="spare_slots"):
            make_engine(_toy(), self._cfg(spare_slots=W))

    def test_join_round_must_be_positive(self):
        with pytest.raises(ValueError, match="join"):
            make_engine(
                _toy(),
                self._cfg(
                    spare_slots=1, membership=MembershipPlan(joins=((0, W - 1),))
                ),
            )

    def test_join_slot_must_be_a_spare(self):
        with pytest.raises(ValueError, match="spare"):
            make_engine(
                _toy(),
                self._cfg(spare_slots=1, membership=MembershipPlan(joins=((2, 0),))),
            )

    def test_duplicate_join_slot_rejected(self):
        with pytest.raises(ValueError, match="slot"):
            make_engine(
                _toy(),
                self._cfg(
                    spare_slots=2,
                    membership=MembershipPlan(joins=((2, W - 1), (3, W - 1))),
                ),
            )


class TestDropExact:
    """Drop is measured vs clean, but the masks are substrate-
    independent: the faulted run itself is bit-identical everywhere."""

    @pytest.mark.parametrize(
        "tag,needs_mesh,kw", SUBSTRATES[1:], ids=SUBSTRATE_IDS[1:]
    )
    def test_drop_bit_identical_across_substrates(self, tag, needs_mesh, kw):
        oracle = _run(fault_plan=DROP, **SUBSTRATES[0][2])
        assert oracle.messages_dropped_injected > 0
        res = _run(needs_mesh, fault_plan=DROP, **kw)
        _same_run(res, oracle, tag)
        assert res.messages_dropped_injected == oracle.messages_dropped_injected

    def test_drop_counted_and_monotone(self):
        res = _run(fault_plan=DROP)
        assert res.messages_dropped_injected > 0
        _monotone_history(res)

    def test_partition_window_is_inert_without_pods(self):
        """The partition fault drops CROSS-POD edges; a single-tier run
        has none, so a partition-only plan is bit-identical to clean."""
        clean = _run()
        part = _run(fault_plan=FaultPlan(partition_start=4, partition_stop=12, seed=1))
        _same_run(part, clean, "single-tier partition")
        assert part.messages_dropped_injected == 0

    def test_partition_drops_cross_pod_traffic(self):
        res = _run("pod", fault_plan=FaultPlan(partition_start=4, partition_stop=12, seed=1))
        assert res.messages_dropped_injected > 0
        assert res.rounds == ROUNDS
        _monotone_history(res)


class TestDuplicationExact:
    """Under uniform delay + adequate capacity, duplication == clean:
    identical copies tie in the delivery argmin and clear together."""

    @pytest.mark.parametrize(
        "tag,needs_mesh,kw",
        [s for s in SUBSTRATES if s[2].get("inflight_capacity")],
        ids=[s[0] for s in SUBSTRATES if s[2].get("inflight_capacity")],
    )
    def test_duplication_identical_to_clean_on_queues(self, tag, needs_mesh, kw):
        clean = _run(needs_mesh, **kw)
        dup = _run(needs_mesh, fault_plan=DUP, **kw)
        _same_run(dup, clean, tag)
        assert dup.messages_evicted == 0

    def test_duplication_single_device_queue(self):
        clean = _run(inflight_capacity=16)
        dup = _run(inflight_capacity=16, fault_plan=DUP)
        _same_run(dup, clean, "single-device dup")
        assert dup.messages_evicted == 0

    def test_dense_buffer_absorbs_duplicates(self):
        """One slot per (dst, src, ring) edge: a duplicate overwrites an
        identical copy of itself — the dense path is inherently immune."""
        clean = _run(inflight_capacity=0)
        dup = _run(inflight_capacity=0, fault_plan=DUP)
        _same_run(dup, clean, "dense dup")


class TestCorruptionSoundness:
    """The eps-gate soundness check: corrupted certificates (NaN, -inf,
    or inflated) are rejected at push time and can never poison a queue
    or alter the best certificate. Loss of the corrupted message's
    legitimate content is measured, not exact."""

    @pytest.mark.parametrize("tag,needs_mesh,kw", SUBSTRATES, ids=SUBSTRATE_IDS)
    def test_corrupt_rejected_on_every_substrate(self, tag, needs_mesh, kw):
        oracle = _run(fault_plan=CORRUPT, **SUBSTRATES[0][2])
        res = _run(needs_mesh, fault_plan=CORRUPT, **kw)
        assert res.messages_corrupt_rejected > 0
        # Same hash -> same rejections -> bit-identical faulted run.
        _same_run(res, oracle, tag)
        assert res.messages_corrupt_rejected == oracle.messages_corrupt_rejected

    def test_corruption_never_poisons_state(self):
        res = _run(fault_plan=CORRUPT)
        assert all(np.isfinite(res.final_certificates))
        _monotone_history(res)

    def test_corruption_preserves_best_certificate(self):
        """Corruption touches in-flight copies, never local state: the
        best worker's locally-earned minimum survives any corruption."""
        clean = _run()
        cor = _run(fault_plan=CORRUPT)
        assert min(cor.final_certificates) == min(clean.final_certificates)

    def test_low_rate_corruption_identical_to_clean(self):
        """When no corrupted message would have been adopted, rejection
        is provably invisible — pinned at a seed where that holds."""
        clean = _run(inflight_capacity=16)
        cor = _run(
            inflight_capacity=16, fault_plan=FaultPlan(corrupt_prob=0.02, seed=14)
        )
        assert cor.messages_corrupt_rejected > 0
        _same_run(cor, clean, "low-rate corruption")


class TestReorderMeasured:
    def test_reorder_completes_and_stays_monotone(self):
        res = _run(inflight_capacity=16, fault_plan=FaultPlan(reorder_max=2, seed=11))
        assert res.rounds == ROUNDS
        assert all(np.isfinite(res.final_certificates))
        _monotone_history(res)

    def test_reorder_requires_queue_inflight(self):
        """The dense buffer derives ring slots from the static delay
        matrix; due-round jitter needs the explicit queue representation."""
        with pytest.raises(ValueError, match="reorder"):
            make_engine(
                _toy(),
                EngineConfig(
                    n_workers=W,
                    max_rounds=4,
                    inflight_capacity=0,
                    fault_plan=FaultPlan(reorder_max=1, seed=1),
                    fault_spec="",
                ),
            )

    def test_reorder_deterministic(self):
        plan = FaultPlan(reorder_max=2, seed=11)
        a = _run(inflight_capacity=16, fault_plan=plan)
        b = _run(inflight_capacity=16, fault_plan=plan)
        _same_run(a, b, "reorder replay")


class TestComposedChaos:
    """Everything at once: drops + duplicates + corruption + churn must
    still complete, stay monotone, and account every counter."""

    def test_full_chaos_completes(self):
        res = _run(
            inflight_capacity=16,
            spare_slots=2,
            membership=MembershipPlan(joins=((6, 6), (10, 7)), leaves=((12, 0),)),
            fault_plan=FaultPlan(
                drop_prob=0.1, duplicate_prob=0.1, corrupt_prob=0.1, seed=13
            ),
        )
        assert res.rounds == ROUNDS
        assert res.messages_dropped_injected > 0
        assert res.messages_corrupt_rejected > 0
        assert res.workers_joined == 2
        _monotone_history(res)

    @needs_devices
    def test_full_chaos_identical_single_vs_sharded(self):
        kw = dict(
            inflight_capacity=16,
            spare_slots=2,
            membership=MembershipPlan(joins=((6, 6),), leaves=((12, 0),)),
            fault_plan=FaultPlan(drop_prob=0.1, corrupt_prob=0.1, seed=13),
        )
        single = _run(**kw)
        sharded = _run(True, **kw)
        _same_run(sharded, single, "composed chaos")
        assert sharded.messages_dropped_injected == single.messages_dropped_injected
        assert sharded.messages_corrupt_rejected == single.messages_corrupt_rejected


class TestAutoCapacityUnderChurn:
    """``inflight_capacity="auto"`` warm-up probe vs membership events
    inside the warm-up window (satellite: the probe must pick a sane
    capacity when workers fail-stop or join during warm-up)."""

    def _membership(self):
        # Warm-up is min(max(2*depth+2, 8), max_rounds) = 8 rounds at
        # delay 1: both events land INSIDE the probe window.
        return dict(
            spare_slots=1,
            membership=MembershipPlan(joins=((4, W - 1),), leaves=((6, 0),)),
        )

    def test_auto_capacity_with_churn_in_warmup(self):
        auto = _run(inflight_capacity="auto", **self._membership())
        assert auto.inflight_capacity_selected >= 1
        explicit = _run(
            inflight_capacity=auto.inflight_capacity_selected, **self._membership()
        )
        _same_run(auto, explicit, "auto vs explicit under churn")
        assert auto.messages_evicted == 0

    def test_auto_capacity_with_failstop_in_warmup(self):
        fail = np.full(W, ROUNDS + 1, dtype=np.int64)
        fail[:2] = 3  # inside the 8-round warm-up window
        auto = _run(inflight_capacity="auto", fail_round=fail.copy())
        assert auto.inflight_capacity_selected >= 1
        explicit = _run(
            inflight_capacity=auto.inflight_capacity_selected, fail_round=fail.copy()
        )
        _same_run(auto, explicit, "auto vs explicit under fail-stop")
        assert auto.messages_evicted == 0


class TestFaultSpecEnv:
    """REPRO_FAULT_PLAN spec string round-trips (constructor-arg form;
    the env hardening lives in test_engine_config.py)."""

    def test_spec_parses_all_fields(self):
        p = _parse_fault_spec("drop=5,dup=2,corrupt=2,reorder=1,seed=9,part=8:16")
        assert (p.drop_prob, p.duplicate_prob, p.corrupt_prob) == (0.05, 0.02, 0.02)
        assert (p.reorder_max, p.seed) == (1, 9)
        assert (p.partition_start, p.partition_stop) == (8, 16)

    def test_inactive_specs_normalize_to_none(self):
        assert _parse_fault_spec("") is None
        assert _parse_fault_spec("drop=0") is None
        assert _parse_fault_spec("seed=9") is None

    def test_spec_equivalent_to_plan(self):
        via_spec = _run(fault_spec="drop=30,seed=7")
        via_plan = _run(fault_plan=FaultPlan(drop_prob=0.3, seed=7))
        _same_run(via_spec, via_plan, "spec vs plan")

    def test_plan_beats_spec(self):
        res = _run(fault_spec="drop=90,seed=1", fault_plan=FaultPlan(drop_prob=0.3, seed=7))
        ref = _run(fault_plan=FaultPlan(drop_prob=0.3, seed=7))
        _same_run(res, ref, "plan precedence")
