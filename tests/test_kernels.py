"""Per-kernel shape/dtype sweeps asserting allclose vs the ref.py
oracles (kernels run in interpret mode on CPU; TPU is the target)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.boosting.stumps import append_stump, empty_model
from repro.core.engine_sharded import sharded_engine_available
from repro.kernels import ops
from repro.kernels.ref import (
    edge_scan_ref,
    margin_delta_oracle,
    queue_ingest_ref,
    round_step_ref,
    weight_update_ref,
)
from repro.kernels.weight_update import scatter_model_slice


def _rand_inputs(key, n, d, num_bins, wdtype):
    k1, k2, k3 = jax.random.split(key, 3)
    xb = jax.random.randint(k1, (n, d), 0, num_bins, dtype=jnp.int32)
    w = (jax.random.uniform(k2, (n,)) + 0.05).astype(wdtype)
    y = jnp.where(jax.random.bernoulli(k3, 0.5, (n,)), 1.0, -1.0).astype(wdtype)
    return xb, w, y


class TestEdgeScanKernel:
    @pytest.mark.parametrize("n", [1, 7, 512, 513, 2048])
    @pytest.mark.parametrize("d,num_bins", [(4, 8), (16, 16), (33, 5)])
    def test_matches_ref(self, n, d, num_bins):
        key = jax.random.PRNGKey(n * 131 + d)
        xb, w, y = _rand_inputs(key, n, d, num_bins, jnp.float32)
        wy = w * y
        hist, W, V, T = ops.edge_scan(xb, wy, w, num_bins=num_bins, tile_n=256, interpret=True)
        rh, rW, rV, rT = edge_scan_ref(xb, wy, w, num_bins)
        np.testing.assert_allclose(np.asarray(hist), np.asarray(rh), rtol=1e-5, atol=1e-5)
        assert float(W) == pytest.approx(float(rW), rel=1e-5)
        assert float(V) == pytest.approx(float(rV), rel=1e-5)
        assert float(T) == pytest.approx(float(rT), rel=1e-4, abs=1e-3)

    @pytest.mark.parametrize("wdtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, wdtype):
        key = jax.random.PRNGKey(0)
        xb, w, y = _rand_inputs(key, 300, 8, 8, wdtype)
        wy = (w * y).astype(jnp.float32)
        w32 = w.astype(jnp.float32)
        hist, W, V, T = ops.edge_scan(xb, wy, w32, num_bins=8, interpret=True)
        rh, *_ = edge_scan_ref(xb, wy, w32, 8)
        tol = 1e-2 if wdtype == jnp.bfloat16 else 1e-5
        np.testing.assert_allclose(np.asarray(hist), np.asarray(rh), rtol=tol, atol=tol)

    def test_tile_size_invariance(self):
        key = jax.random.PRNGKey(5)
        xb, w, y = _rand_inputs(key, 1000, 12, 8, jnp.float32)
        wy = w * y
        out128 = ops.edge_scan(xb, wy, w, num_bins=8, tile_n=128, interpret=True)
        out512 = ops.edge_scan(xb, wy, w, num_bins=8, tile_n=512, interpret=True)
        np.testing.assert_allclose(np.asarray(out128[0]), np.asarray(out512[0]), rtol=1e-5)

    def test_batched_matches_per_worker(self):
        """vmap over the pallas_call (one launch, batch grid dim) must
        equal W independent kernel calls — the batched-scanner contract."""
        key = jax.random.PRNGKey(9)
        W, n, d, num_bins = 3, 300, 6, 8
        xbs, ws, ys = [], [], []
        for i in range(W):
            xb, w, y = _rand_inputs(jax.random.fold_in(key, i), n, d, num_bins, jnp.float32)
            xbs.append(xb)
            ws.append(w)
            ys.append(y)
        xb_b = jnp.stack(xbs)
        w_b = jnp.stack(ws)
        wy_b = jnp.stack([w * y for w, y in zip(ws, ys)])
        hist_b, W_b, V_b, T_b = ops.edge_scan_batched(
            xb_b, wy_b, w_b, num_bins=num_bins, tile_n=128, interpret=True
        )
        assert hist_b.shape == (W, d, num_bins)
        for i in range(W):
            hist, Wi, Vi, Ti = ops.edge_scan(
                xbs[i], wy_b[i], ws[i], num_bins=num_bins, tile_n=128, interpret=True
            )
            np.testing.assert_allclose(np.asarray(hist_b[i]), np.asarray(hist), rtol=1e-5, atol=1e-5)
            assert float(W_b[i]) == pytest.approx(float(Wi), rel=1e-5)
            assert float(V_b[i]) == pytest.approx(float(Vi), rel=1e-5)
            assert float(T_b[i]) == pytest.approx(float(Ti), rel=1e-4, abs=1e-3)

    @pytest.mark.skipif(
        not sharded_engine_available(), reason="sharded edge scan needs >=2 devices"
    )
    def test_sharded_matches_batched(self):
        """shard_map over the workers axis (each device runs the vmapped
        pallas_call on its local shard) must equal the single-device
        batched launch — the sharded-engine scan-path contract."""
        from repro.launch.mesh import make_worker_mesh

        key = jax.random.PRNGKey(13)
        n_dev = len(jax.devices())
        W, n, d, num_bins = 2 * n_dev, 300, 6, 8
        xb_b = jnp.stack(
            [_rand_inputs(jax.random.fold_in(key, i), n, d, num_bins, jnp.float32)[0]
             for i in range(W)]
        )
        per = [_rand_inputs(jax.random.fold_in(key, 100 + i), n, d, num_bins, jnp.float32)
               for i in range(W)]
        w_b = jnp.stack([w for _, w, _ in per])
        wy_b = jnp.stack([w * y for _, w, y in per])
        ref = ops.edge_scan_batched(xb_b, wy_b, w_b, num_bins=num_bins, tile_n=128, interpret=True)
        got = ops.edge_scan_sharded(
            xb_b, wy_b, w_b, mesh=make_worker_mesh(), num_bins=num_bins, tile_n=128,
            interpret=True,
        )
        for g, r in zip(got, ref):
            np.testing.assert_allclose(np.asarray(g), np.asarray(r), rtol=1e-5, atol=1e-5)

    def test_padding_rows_do_not_leak(self):
        """n not a multiple of tile_n: padded rows must contribute zero."""
        key = jax.random.PRNGKey(6)
        xb, w, y = _rand_inputs(key, 100, 4, 8, jnp.float32)
        wy = w * y
        hist, W, V, T = ops.edge_scan(xb, wy, w, num_bins=8, tile_n=64, interpret=True)
        rh, rW, _, _ = edge_scan_ref(xb, wy, w, 8)
        np.testing.assert_allclose(np.asarray(hist), np.asarray(rh), rtol=1e-5, atol=1e-5)
        assert float(W) == pytest.approx(float(rW), rel=1e-5)


class TestWeightUpdateKernel:
    @pytest.mark.parametrize("n", [5, 512, 777])
    @pytest.mark.parametrize("d,num_bins", [(8, 8), (16, 32)])
    def test_matches_ref(self, n, d, num_bins):
        key = jax.random.PRNGKey(n + d)
        k1, k2, k3, k4 = jax.random.split(key, 4)
        xb = jax.random.randint(k1, (n, d), 0, num_bins, dtype=jnp.int32)
        y = jnp.where(jax.random.bernoulli(k2, 0.5, (n,)), 1.0, -1.0)
        ml = jax.random.normal(k3, (n,)) * 0.5
        ms = jax.random.normal(k4, (n,)) * 0.5
        a = jax.random.normal(key, (d, num_bins - 1)) * 0.1
        c = jnp.sum(a) * 0.3
        m_new, w = ops.weight_update(
            xb, y, ml, ms, a, c, num_bins=num_bins, tile_n=256, interpret=True
        )
        rm, rw = weight_update_ref(xb, y, ml, ms, a, c, num_bins)
        np.testing.assert_allclose(np.asarray(m_new), np.asarray(rm), rtol=1e-4, atol=1e-5)
        np.testing.assert_allclose(np.asarray(w), np.asarray(rw), rtol=1e-4, atol=1e-5)

    def test_scatter_slice_semantics(self):
        """scatter_model_slice + kernel == stump-by-stump margin delta."""
        d, num_bins, n = 6, 8, 64
        key = jax.random.PRNGKey(7)
        xb = jax.random.randint(key, (n, d), 0, num_bins, dtype=jnp.int32)
        model = empty_model(16)
        rng = np.random.default_rng(0)
        for k in range(10):
            model = append_stump(
                model,
                int(rng.integers(0, d)),
                int(rng.integers(0, num_bins - 1)),
                float(rng.choice([-1.0, 1.0])),
                float(rng.uniform(0.1, 1.0)),
            )
        t_lo, t_hi = 3, 10
        a, c = scatter_model_slice(model, t_lo, t_hi, num_bins, d)
        y = jnp.ones((n,))
        zeros = jnp.zeros((n,))
        m_new, _ = ops.weight_update(xb, y, zeros, zeros, a, c, num_bins=num_bins, interpret=True)
        oracle = margin_delta_oracle(model, xb, t_lo, t_hi)
        np.testing.assert_allclose(np.asarray(m_new), np.asarray(oracle), rtol=1e-4, atol=1e-5)

    def test_weight_clipping(self):
        """Extreme margins must not produce inf/nan."""
        xb = jnp.zeros((4, 2), jnp.int32)
        y = jnp.asarray([1.0, -1.0, 1.0, -1.0])
        ml = jnp.asarray([100.0, -100.0, 0.0, 0.0])
        ms = jnp.zeros((4,))
        a = jnp.zeros((2, 7))
        m_new, w = ops.weight_update(xb, y, ml, ms, a, 0.0, num_bins=8, interpret=True)
        assert np.isfinite(np.asarray(w)).all()


def _round_step_inputs(key, w, cap, fill=0.6):
    ks = jax.random.split(key, 8)
    q_cert = jnp.where(
        jax.random.uniform(ks[0], (w, cap)) < fill,
        -jax.random.uniform(ks[1], (w, cap)) - 0.01,
        jnp.inf,
    )
    q_due = jax.random.randint(ks[2], (w, cap), 0, 4, dtype=jnp.int32)
    q_src = jax.random.randint(ks[3], (w, cap), 0, w, dtype=jnp.int32)
    q_slot = jax.random.randint(ks[4], (w, cap), 0, 3, dtype=jnp.int32)
    certs0 = -jax.random.uniform(ks[5], (w,))
    alive = jax.random.bernoulli(ks[6], 0.8, (w,))
    credit = jax.random.uniform(ks[7], (w,))
    speed = jnp.linspace(0.2, 1.0, w)
    return q_cert, q_due, q_src, q_slot, certs0, alive, credit, speed


class TestRoundStepKernel:
    """Fused sparse delivery + accept + credit vs the jnp oracle. The
    contract is BIT-identical (both paths are exact-comparison/argmin
    logic, no accumulation), so assertions use array_equal."""

    @pytest.mark.parametrize("w", [1, 7, 128, 200])
    @pytest.mark.parametrize("cap", [1, 5, 32])
    def test_matches_ref(self, w, cap):
        args = _round_step_inputs(jax.random.PRNGKey(w * 37 + cap), w, cap)
        for r in (0, 2):
            ref = round_step_ref(*args, jnp.int32(r), eps=0.01)
            got = ops.round_deliver(*args, jnp.int32(r), eps=0.01, interpret=True)
            for name, a, b in zip(
                ["q_cert", "best_cert", "best_src", "best_slot",
                 "take", "n_arr", "credit", "active"], ref, got,
            ):
                assert a.dtype == b.dtype, name
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)

    def test_tile_size_invariance_and_padding(self):
        """w not a multiple of tile_w pads rows; padded rows must not
        leak into the trimmed outputs."""
        args = _round_step_inputs(jax.random.PRNGKey(3), 100, 4)
        outs = [
            ops.round_deliver(*args, jnp.int32(1), eps=0.0, tile_w=tw, interpret=True)
            for tw in (8, 64, 256)
        ]
        for got in outs[1:]:
            for a, b in zip(outs[0], got):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_empty_queue_delivers_nothing(self):
        w, cap = 9, 3
        q_cert = jnp.full((w, cap), jnp.inf)
        zi = jnp.zeros((w, cap), jnp.int32)
        out = ops.round_deliver(
            q_cert, jnp.full((w, cap), -1, jnp.int32), zi, zi,
            jnp.zeros((w,)), jnp.ones((w,), bool), jnp.zeros((w,)),
            jnp.ones((w,)), jnp.int32(0), eps=0.0, interpret=True,
        )
        assert not bool(out[4].any())  # no take
        assert int(out[5].sum()) == 0  # no arrivals
        assert bool(out[7].all())  # every alive worker is credit-active


def _ingest_inputs(key, w, cap, m, fill=0.6):
    """Random occupied queues + a candidate block: finite certs mark
    occupied/valid entries, +inf the empty/invalid ones (the engine's
    OOB-padded candidates arrive exactly like this)."""
    ks = jax.random.split(key, 8)
    q_cert = jnp.where(
        jax.random.uniform(ks[0], (w, cap)) < fill,
        -jax.random.uniform(ks[1], (w, cap)) - 0.01,
        jnp.inf,
    )
    q_due = jax.random.randint(ks[2], (w, cap), 0, 6, dtype=jnp.int32)
    q_src = jax.random.randint(ks[3], (w, cap), 0, w, dtype=jnp.int32)
    q_slot = jax.random.randint(ks[4], (w, cap), 0, 3, dtype=jnp.int32)
    c_cert = jnp.where(
        jax.random.uniform(ks[5], (w, m)) < fill,
        -jax.random.uniform(ks[6], (w, m)) - 0.01,
        jnp.inf,
    )
    c_due = jax.random.randint(ks[7], (w, m), 0, 6, dtype=jnp.int32)
    c_src = jax.random.randint(ks[0], (w, m), 0, w, dtype=jnp.int32)
    c_slot = jax.random.randint(ks[1], (w, m), 0, 3, dtype=jnp.int32)
    return q_cert, q_due, q_src, q_slot, c_cert, c_due, c_src, c_slot


class TestQueueIngestKernel:
    """Fused sparse-control candidate-list ingest vs the jnp oracle.
    Pure comparison/permutation logic, so assertions are array_equal."""

    @pytest.mark.parametrize("w", [1, 7, 128, 200])
    @pytest.mark.parametrize("cap,m", [(1, 1), (4, 3), (8, 16), (32, 8)])
    def test_matches_ref(self, w, cap, m):
        args = _ingest_inputs(jax.random.PRNGKey(w * 31 + cap + m), w, cap, m)
        ref = queue_ingest_ref(*args)
        got = ops.queue_ingest(*args, interpret=True)
        for name, a, b in zip(["cert", "due", "src", "slot"], ref, got):
            assert a.dtype == b.dtype, name
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b), err_msg=name)

    def test_tile_size_invariance_and_padding(self):
        """w not a multiple of tile_w pads rows; padded rows must not
        leak into the trimmed outputs."""
        args = _ingest_inputs(jax.random.PRNGKey(5), 100, 6, 8)
        outs = [
            ops.queue_ingest(*args, tile_w=tw, interpret=True)
            for tw in (8, 64, 256)
        ]
        for got in outs[1:]:
            for a, b in zip(outs[0], got):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_no_valid_candidates_is_a_noop(self):
        """An all-invalid (+inf) candidate block must leave every
        OCCUPIED queue entry bitwise unchanged — the engine relies on
        this every round no device improves. Fully-occupied queues make
        the claim exact (empty +inf slots may swap their garbage for
        the candidates' +inf padding, which delivery can never match)."""
        w, cap, m = 9, 4, 5
        q_cert, q_due, q_src, q_slot, *_ = _ingest_inputs(
            jax.random.PRNGKey(11), w, cap, m, fill=1.0
        )
        # a fully occupied queue sorts to itself only when already in
        # (cert, src, due) order — pre-sort so the no-op claim is exact
        order = jnp.lexsort((q_due, q_src, q_cert), axis=-1)
        q_cert = jnp.take_along_axis(q_cert, order, axis=1)
        q_due = jnp.take_along_axis(q_due, order, axis=1)
        q_src = jnp.take_along_axis(q_src, order, axis=1)
        q_slot = jnp.take_along_axis(q_slot, order, axis=1)
        empty = (
            jnp.full((w, m), jnp.inf),
            jnp.zeros((w, m), jnp.int32),
            jnp.full((w, m), -1, jnp.int32),
            jnp.zeros((w, m), jnp.int32),
        )
        got = ops.queue_ingest(q_cert, q_due, q_src, q_slot, *empty, interpret=True)
        for a, b in zip((q_cert, q_due, q_src, q_slot), got):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_worst_first_eviction_keeps_best(self):
        """Overflow keeps the lexicographically smallest (cert, src,
        due) entries across queue + candidates."""
        q_cert = jnp.asarray([[-1.0, -3.0]], jnp.float32)
        q_due = jnp.asarray([[4, 4]], jnp.int32)
        q_src = jnp.asarray([[2, 5]], jnp.int32)
        q_slot = jnp.asarray([[0, 1]], jnp.int32)
        c_cert = jnp.asarray([[-2.0, jnp.inf]], jnp.float32)
        c_due = jnp.asarray([[6, 0]], jnp.int32)
        c_src = jnp.asarray([[7, -1]], jnp.int32)
        c_slot = jnp.asarray([[2, 0]], jnp.int32)
        cert, due, src, slot = ops.queue_ingest(
            q_cert, q_due, q_src, q_slot, c_cert, c_due, c_src, c_slot,
            interpret=True,
        )
        np.testing.assert_array_equal(np.asarray(cert), [[-3.0, -2.0]])
        np.testing.assert_array_equal(np.asarray(src), [[5, 7]])
        np.testing.assert_array_equal(np.asarray(due), [[4, 6]])
        np.testing.assert_array_equal(np.asarray(slot), [[1, 2]])


class TestKernelScannerEquivalence:
    def test_edge_scan_reproduces_scanner_histogram(self):
        """The kernel path and the scanner's pure-jnp path agree on the
        quantities the stopping rule consumes."""
        from repro.boosting.stumps import edge_histogram, edges_from_histogram

        key = jax.random.PRNGKey(8)
        xb, w, y = _rand_inputs(key, 600, 10, 8, jnp.float32)
        wy = w * y
        hist_k, W, V, T = ops.edge_scan(xb, wy, w, num_bins=8, interpret=True)
        hist_j = edge_histogram(xb, wy, 8)
        np.testing.assert_allclose(np.asarray(hist_k), np.asarray(hist_j), rtol=1e-5, atol=1e-5)
        ek = edges_from_histogram(hist_k)
        ej = edges_from_histogram(hist_j)
        np.testing.assert_allclose(np.asarray(ek), np.asarray(ej), rtol=1e-5, atol=1e-5)
