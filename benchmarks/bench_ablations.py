"""Ablations over the paper's design choices:

  * minimal-variance vs rejection sampling (paper footnote 4: MVS chosen
    "because it produces less variation in the sampled set"),
  * gamma policy after a fire ("track" vs the pseudocode's "keep"),
  * ESS resampling threshold,
  * ownership redundancy r (beyond-paper).
"""

from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.boosting import SparrowConfig, SparrowWorker
from repro.boosting.sampler import inclusion_counts, minimal_variance_sample, rejection_sample
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import exp_loss
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def sampler_variance(trials: int = 50) -> dict:
    """Variance of inclusion counts: MVS should be much lower (the
    paper's stated reason for choosing it)."""
    key = jax.random.PRNGKey(0)
    w = jnp.exp(jax.random.normal(jax.random.fold_in(key, 1), (512,)))
    m = 256
    var = {}
    for name, fn in (("mvs", minimal_variance_sample), ("rejection", rejection_sample)):
        counts = []
        for t in range(trials):
            idx = fn(jax.random.fold_in(key, 100 + t), w, m)
            counts.append(np.asarray(inclusion_counts(idx, 512)))
        var[name] = float(np.mean(np.var(np.stack(counts), axis=0)))
    return var


def _run_sparrow(xtr, ytr, xte, yte, events=900, **over):
    scan_over = {k: v for k, v in over.items() if k in ScannerConfig._fields}
    cfg_over = {k: v for k, v in over.items() if k not in ScannerConfig._fields}
    cfg = SparrowConfig(
        sample_size=max(xtr.shape[0] // 10, 1024),
        capacity=256,
        scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25, **scan_over),
        mem_read_cost=0.25,
        disk_read_cost=1.0,
        **cfg_over,
    )
    w = SparrowWorker(xtr, ytr, cfg)
    sim = TMSNSimulator(w, [WorkerSpec()], SimulatorConfig(n_workers=1, max_events=events, eps=0.0))
    r = sim.run()
    return {
        "loss": float(exp_loss(r.final_models[0], xte, yte)),
        "cost": r.cost_units_total,
        "stumps": int(r.final_models[0].count),
    }


def run(quick: bool = False) -> list[str]:
    lines = []
    var = sampler_variance(20 if quick else 60)
    lines.append(f"ablations.sampler_count_variance_mvs,{var['mvs']:.4f},")
    lines.append(f"ablations.sampler_count_variance_rejection,{var['rejection']:.4f},")
    lines.append(
        f"ablations.mvs_variance_reduction,{var['rejection']/max(var['mvs'],1e-9):.1f},x_lower_is_paper_claim"
    )

    xb, y, _ = make_splice_like(SpliceConfig(n=30_000, d=32, num_bins=8, seed=5))
    xtr, ytr, xte, yte = train_test_split(xb, y)
    ev = 700 if quick else 1600

    out = {"sampler_variance": var}
    for tag, over in [
        ("gamma_track", dict(gamma_policy="track")),
        ("gamma_keep", dict(gamma_policy="keep")),
        ("ess_0.05", dict(ess_threshold=0.05)),
        ("ess_0.3", dict(ess_threshold=0.3)),
    ]:
        r = _run_sparrow(xtr, ytr, xte, yte, events=ev, **over)
        out[tag] = r
        lines.append(f"ablations.{tag},{r['loss']:.4f},stumps={r['stumps']}_cost={r['cost']:.2e}")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "ablations.json"), "w") as f:
        json.dump(out, f, indent=1, default=float)
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
