"""Benchmark regression guard.

Compares a ``bench_results.json`` run (the output of
``python -m benchmarks.run --json``) against the committed
``benchmarks/baseline.json`` and exits non-zero when a guarded metric
regresses past its tolerance — the full CI tier *fails* on a real
slowdown instead of silently uploading artifacts.

  python -m benchmarks.check_regression bench_results.json
  python -m benchmarks.check_regression --write-baseline bench_results.json

Baseline schema::

  {
    "schema_version": 1,
    "metrics": {
      "scaling.w8.rounds_to_target": {"value": 21, "tolerance": 0.2},
      ...
    }
  }

Every guarded metric is lower-is-better; a run fails when
``current > value * (1 + tolerance * scale)``. Metrics present in only
one of baseline/current (a guarded metric missing from the results, or
a guardable result not yet baselined) WARN instead of failing — newly
added benchmark metrics and baseline entries can land in either order
without breaking the other side's CI; rebaseline to re-tighten
coverage. Protocol
metrics (rounds-to-target, gossip bytes) get the tight 20% tolerance;
wall-clock metrics carry a wider default (+55 points) because the
baseline machine and the CI runner differ — rebaseline from a CI
artifact (download ``bench-results``, re-run with ``--write-baseline
--wall-clock-extra 0``) to drop wall clock to the tight 20% guard.
``--tolerance-scale`` scales every tolerance at once (an escape hatch
for known-noisy runners; 1.0 in CI). Runs are only compared on the
machine shape they were baselined on: the results' ``_schema`` must
match the baseline's recorded ``source`` or the guard refuses.
"""

from __future__ import annotations

import argparse
import fnmatch
import json
import os
import sys

BASELINE = os.path.join(os.path.dirname(os.path.abspath(__file__)), "baseline.json")

#: metrics the baseline snapshots, with per-pattern tolerances
#: (lower-is-better for every one of them)
GUARDED = [
    ("scaling.w*.rounds_to_target", 0.20),
    ("scaling.w*.wall_ms_per_round", 0.20),
    # matches both the dense `sharded_wN` and the `sharded_wN_gated`
    # variants (gossip bytes are exact per mode, so the tight guard
    # catches any accounting or gating regression)
    ("scaling.sharded_w*.wall_ms_per_round", 0.20),
    ("scaling.sharded_w*.gossip_bytes_per_round", 0.20),
    ("scaling.dispatch_w*.wall_ms_per_round", 0.20),
    # sparse pending-queue sweeps (uniform, het-delay, and the capped
    # W=4096 run dense cannot complete) plus the fused round kernel's
    # projected HBM floor (deterministic — drift means the kernel's
    # operand footprint changed)
    ("scaling.sparse_w*.wall_ms_per_round", 0.20),
    ("scaling.round_step_w*.projected_us", 0.20),
    # control-plane sweep (dense certs/flags vs top-k triples): the
    # byte figures are exact formulas, so the tight guard catches any
    # control-accounting regression; wall clock gets the usual headroom
    ("scaling.ctrl_w*.wall_ms_per_round", 0.20),
    ("scaling.ctrl_w*.control_bytes_per_round", 0.20),
    # hierarchical (pod, workers) mesh: per-tier footprints are exact
    # formulas (any drift is an accounting regression), wall clock gets
    # the usual cross-machine headroom until rebaselined
    ("scaling.pod2_w*.wall_ms_per_round", 0.20),
    ("scaling.pod2_w*.ici_bytes_per_round", 0.20),
    ("scaling.pod2_w*.dcn_bytes_per_round", 0.20),
    # engine-hosted TMSN-SGD (bench_tmsn_sgd.py, --tiny tier): protocol
    # metrics on fixed seeds — WARN until the baseline is regenerated
    # with them, then guarded like the scaling suite
    ("tmsn_sgd.engine_rounds_to_target", 0.20),
    ("tmsn_sgd.engine_bytes_broadcast", 0.20),
    # chaos resilience section (bench_scaling.run_chaos, --tiny tier):
    # the injected/rejected counters are deterministic on the seeded
    # fault plan (drift means the counter-hash or fault accounting
    # changed) and the cert-gap-vs-clean figures are 0.0 at the pinned
    # rates (any nonzero gap after baselining is a resilience
    # regression). WARN until the baseline is regenerated with them
    ("chaos.*_w*.wall_ms_per_round", 0.20),
    ("chaos.*.messages_dropped_injected", 0.20),
    ("chaos.*.messages_corrupt_rejected", 0.20),
    ("chaos.*.best_cert_gap_vs_clean", 0.20),
    # serving tier (bench_serving.py, --tiny tier): request latency and
    # per-step wall get the wall-clock headroom via the name check; the
    # zero-downtime counters baseline at 0, so ANY nonzero reading is a
    # hard failure once baselined; the stale-cert gaps are
    # deterministic on the seeded engine run. Higher-is-better
    # throughput (req_per_s, decode_tok_per_s) is reported but not
    # guarded — the guard is one-sided lower-is-better. WARN until the
    # baseline is regenerated with them
    ("serving.b*.latency_p50_wall_ms", 0.20),
    ("serving.b*.latency_p99_wall_ms", 0.20),
    ("serving.b*.step_p50_wall_ms", 0.20),
    ("serving.adopt.dropped_requests", 0.20),
    ("serving.adopt.recompiles", 0.20),
    ("serving.adopt.blip_p99_wall_ms", 0.20),
    ("serving.adopt.steady_p99_wall_ms", 0.20),
    ("serving.adopt.stale_cert_gap_mean", 0.20),
    ("serving.adopt.stale_cert_gap_max", 0.20),
]

#: wall-clock metrics absorb cross-machine noise until rebaselined from
#: a CI artifact; protocol metrics stay at the tight default
WALL_CLOCK_EXTRA = 0.55  # 0.20 + 0.55 = 75% headroom


def _tolerance_for(name: str, wall_clock_extra: float) -> float | None:
    for pattern, tol in GUARDED:
        if fnmatch.fnmatch(name, pattern):
            if "wall_ms" in name or "_us" in name or "wall_s" in name:
                return tol + wall_clock_extra
            return tol
    return None


def write_baseline(results: dict, path: str, wall_clock_extra: float) -> int:
    metrics = {}
    for name, value in sorted(results.items()):
        if name.startswith("_") or not isinstance(value, (int, float)):
            continue
        tol = _tolerance_for(name, wall_clock_extra)
        if tol is not None:
            metrics[name] = {"value": value, "tolerance": tol}
    schema = results.get("_schema", {})
    source = {k: schema.get(k) for k in ("devices", "backend", "profile")}
    # the RESULTS format version (and the SHA the numbers came from):
    # lets check() flag a cross-version comparison instead of silently
    # comparing metrics whose semantics may have shifted between formats
    source["results_version"] = schema.get("version")
    source["git_sha"] = schema.get("git_sha")
    with open(path, "w") as f:
        json.dump(
            {
                "schema_version": 1,
                "source": source,
                "metrics": metrics,
            },
            f,
            indent=1,
            sort_keys=True,
        )
        f.write("\n")
    print(f"wrote {len(metrics)} guarded metrics to {path}")
    return 0


def check(results: dict, baseline: dict, scale: float) -> int:
    failures = []
    warnings = []
    checked = 0
    # numbers are only comparable on the same machine shape and bench
    # profile — that is what the results' _schema / baseline's source
    # record. A mismatch means "rebaseline", not "regression".
    schema = results.get("_schema", {})
    source = baseline.get("source", {})
    for key in ("devices", "backend", "profile"):
        if source.get(key) is not None and schema.get(key) != source.get(key):
            print(
                f"machine-shape mismatch on '{key}': results {schema.get(key)!r} "
                f"vs baseline {source.get(key)!r} — these runs are not comparable.\n"
                "Rebaseline on this shape with: python -m benchmarks.check_regression "
                "--write-baseline <results.json>"
            )
            return 1
    # same machine shape but a different results-format version: the
    # metrics MAY have shifted meaning between formats, so say so out
    # loud instead of silently comparing (shape matches, so a comparison
    # is still more useful than a refusal — rebaseline to clear this)
    if schema.get("version") != source.get("results_version"):
        print(
            f"WARN: results schema version {schema.get('version')!r} differs from "
            f"the baseline's recorded {source.get('results_version')!r} on a "
            "matching machine shape — comparing anyway, but metric semantics may "
            "have changed between formats; rebaseline with --write-baseline to "
            "clear this warning"
        )
    for name, spec in sorted(baseline["metrics"].items()):
        base_value, tol = spec["value"], spec["tolerance"] * scale
        current = results.get(name)
        if current is None or not isinstance(current, (int, float)):
            # one-sided metric: warn, don't fail — a bench rename or a
            # not-yet-rerun bench shouldn't block unrelated changes
            warnings.append(f"  baseline-only {name} (baseline {base_value:g})")
            continue
        checked += 1
        allowed = base_value * (1.0 + tol)
        status = "FAIL" if current > allowed else "ok"
        print(
            f"  {status:7s}  {name}: {current:g} vs baseline {base_value:g} "
            f"(allowed <= {allowed:g})"
        )
        if current > allowed:
            failures.append(
                f"  REGRESSED {name}: {current:g} > {allowed:g} "
                f"({100 * (current / base_value - 1):+.0f}% vs +{100 * tol:.0f}% allowed)"
            )
    # the other side: guardable metrics in the results with no baseline
    # entry yet — also warn-only, with a pointer at the fix
    for name, value in sorted(results.items()):
        if name.startswith("_") or not isinstance(value, (int, float)):
            continue
        if name not in baseline["metrics"] and _tolerance_for(name, 0.0) is not None:
            warnings.append(f"  current-only  {name} ({value:g}) — not guarded yet")
    print(f"checked {checked}/{len(baseline['metrics'])} guarded metrics")
    if warnings:
        print("\nWARN: metrics present in only one of baseline/current "
              "(rebaseline with --write-baseline to re-tighten coverage):")
        for line in warnings:
            print(line)
    if failures:
        print("\nbenchmark regression guard FAILED:")
        for line in failures:
            print(line)
        return 1
    print("benchmark regression guard passed")
    return 0


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("results", help="bench_results.json from benchmarks.run --json")
    ap.add_argument("--baseline", default=BASELINE)
    ap.add_argument("--write-baseline", action="store_true",
                    help="snapshot the guarded metrics of RESULTS as the new baseline")
    ap.add_argument("--tolerance-scale", type=float, default=1.0)
    ap.add_argument(
        "--wall-clock-extra", type=float, default=WALL_CLOCK_EXTRA,
        help="extra tolerance baked into wall-clock metrics at baseline-write "
        "time; pass 0 when rebaselining from the SAME machine the guard runs "
        "on (e.g. a CI artifact) to get the tight 20%% wall-clock guard",
    )
    args = ap.parse_args()

    with open(args.results) as f:
        results = json.load(f)
    if args.write_baseline:
        return write_baseline(results, args.baseline, args.wall_clock_extra)
    with open(args.baseline) as f:
        baseline = json.load(f)
    if baseline.get("schema_version") != 1:
        print(f"unknown baseline schema_version: {baseline.get('schema_version')}")
        return 1
    return check(results, baseline, args.tolerance_scale)


if __name__ == "__main__":
    sys.exit(main())
