"""TMSN protocol resilience benchmarks (paper §1/§2 claims):

  * laggards: TMSN vs bulk-synchronous under a 10x-slower straggler —
    BSP pays the barrier every round, TMSN pays ~nothing;
  * fail-stop: workers dying mid-run degrade throughput proportionally;
  * communication: messages sent/accepted/discarded and broadcast bytes.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.boosting import SparrowConfig, SparrowWorker
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import exp_loss
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec, run_bsp_baseline
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def _setup(n=40_000, d=32, nw=4):
    xb, y, _ = make_splice_like(SpliceConfig(n=n, d=d, num_bins=8, seed=1))
    xtr, ytr, xte, yte = train_test_split(xb, y)
    cfg = SparrowConfig(
        sample_size=4096,
        capacity=96,
        scanner=ScannerConfig(chunk_size=1024, num_bins=8, gamma0=0.25),
        n_workers=nw,
    )
    return SparrowWorker(xtr, ytr, cfg), (xte, yte)


def run(quick: bool = False) -> list[str]:
    lines = []
    nw = 4
    ev = 800 if quick else 2400
    worker, (xte, yte) = _setup(nw=nw)

    # --- laggard comparison: one worker 10x slower ---
    specs_uniform = [WorkerSpec(speed=1.0) for _ in range(nw)]
    specs_laggard = [WorkerSpec(speed=1.0)] * (nw - 1) + [WorkerSpec(speed=0.1)]

    out = {}
    for tag, specs in [("uniform", specs_uniform), ("laggard", specs_laggard)]:
        sim = TMSNSimulator(worker, specs, SimulatorConfig(n_workers=nw, max_events=ev, seed=2, eps=0.02))
        res = sim.run()
        best = int(np.argmin(res.final_certificates))
        out[f"tmsn_{tag}"] = {
            "cert": res.final_certificates[best],
            "sim_time": res.sim_time,
            "loss": float(exp_loss(res.final_models[best], xte, yte)),
            "msgs": res.messages_sent,
            "accepted": res.messages_accepted,
            "bytes": res.bytes_broadcast,
        }
        bsp = run_bsp_baseline(
            worker, specs,
            SimulatorConfig(n_workers=nw, max_events=ev, seed=2, eps=0.02),
            rounds=ev // (nw * 4),
        )
        bbest = int(np.argmin(bsp.final_certificates))
        out[f"bsp_{tag}"] = {
            "cert": bsp.final_certificates[bbest],
            "sim_time": bsp.sim_time,
            "loss": float(exp_loss(bsp.final_models[bbest], xte, yte)),
            "wait_frac": float(sum(bsp.wait_time) / max(bsp.sim_time * nw, 1e-9)),
        }

    # certificate progress per unit simulated time (higher = better)
    for tag in ("uniform", "laggard"):
        t = out[f"tmsn_{tag}"]
        b = out[f"bsp_{tag}"]
        t_rate = -t["cert"] / max(t["sim_time"], 1e-9)
        b_rate = -b["cert"] / max(b["sim_time"], 1e-9)
        out[f"rate_ratio_{tag}"] = t_rate / max(b_rate, 1e-12)
        lines.append(f"protocol.tmsn_vs_bsp_rate_{tag},{out[f'rate_ratio_{tag}']:.2f},>1_means_tmsn_faster")
    lines.append(f"protocol.bsp_laggard_waitfrac,{out['bsp_laggard']['wait_frac']:.3f},barrier_idle_fraction")
    lines.append(
        "protocol.tmsn_msgs_accept_rate,"
        f"{out['tmsn_uniform']['accepted'] / max(out['tmsn_uniform']['msgs'], 1):.3f},"
    )

    # --- fail-stop: 1 of 4 workers dies early ---
    # r=1 (paper: disjoint feature ownership) loses part of the
    # hypothesis space; r=2 (beyond-paper redundant ownership) recovers.
    specs_fail = [WorkerSpec()] * (nw - 1) + [WorkerSpec(fail_at=50.0)]
    for r in (1, 2):
        import dataclasses as _dc

        w2 = SparrowWorker(worker.xb, worker.y, _dc.replace(worker.config, ownership_redundancy=r))
        sim = TMSNSimulator(w2, specs_fail, SimulatorConfig(n_workers=nw, max_events=ev, seed=3, eps=0.02))
        res = sim.run()
        live_best = float(np.min(res.final_certificates[: nw - 1]))
        out[f"tmsn_failstop_cert_r{r}"] = live_best
        degraded = live_best / min(out["tmsn_uniform"]["cert"], -1e-9)
        lines.append(f"protocol.failstop_cert_ratio_r{r},{degraded:.2f},1.0=no_degradation")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "protocol.json"), "w") as f:
        json.dump(out, f, indent=1, default=float)
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
