"""Kernel micro-benchmarks. On this CPU container the Pallas kernels
run in interpret mode (host-speed, NOT TPU-representative) — reported
as correctness + host-overhead numbers; the TPU projection column uses
the analytic VMEM-tile roofline from the kernel's block shapes."""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.boosting.stumps import edge_histogram
from repro.kernels import ops
from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16


def _time(f, *args, reps=3):
    f(*args)  # warm/compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(f(*args))
    return (time.time() - t0) / reps * 1e6  # us


def run(quick: bool = False) -> list[str]:
    lines = []
    n, d, B = (4096, 32, 8) if quick else (16384, 64, 8)
    key = jax.random.PRNGKey(0)
    xb = jax.random.randint(key, (n, d), 0, B, dtype=jnp.int32)
    w = jax.random.uniform(key, (n,)) + 0.1
    y = jnp.where(jax.random.bernoulli(key, 0.5, (n,)), 1.0, -1.0)
    wy = w * y

    t_jnp = _time(jax.jit(lambda a, b: edge_histogram(a, b, B)), xb, wy)
    t_pallas = _time(
        lambda a, b, c: ops.edge_scan(a, b, c, num_bins=B, interpret=True), xb, wy, w
    )
    lines.append(f"kernels.edge_scan_jnp_cpu,{t_jnp:.0f},us_per_call")
    lines.append(f"kernels.edge_scan_pallas_interp,{t_pallas:.0f},us_per_call_interpret_mode")

    # TPU projection: one pass reads n*d int32 bins + writes (d,B) f32;
    # MXU work = 2*n*d*B flops per tile-contraction
    bytes_moved = n * d * 4 + d * B * 4 + n * 8
    flops = 2 * n * d * B
    t_mem = bytes_moved / HBM_BW * 1e6
    t_mxu = flops / PEAK_FLOPS_BF16 * 1e6
    lines.append(f"kernels.edge_scan_tpu_roofline,{max(t_mem, t_mxu):.2f},us_projected_bw_bound")

    a = jax.random.normal(key, (d, B - 1)) * 0.1
    ml = jnp.zeros((n,))
    t_wu = _time(
        lambda: ops.weight_update(xb, y, ml, ml, a, jnp.sum(a) * 0.1, num_bins=B, interpret=True)
    )
    lines.append(f"kernels.weight_update_pallas_interp,{t_wu:.0f},us_per_call_interpret_mode")
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
