"""Serving-tier benchmark: continuous-batching throughput/latency and
zero-downtime adoption (``repro.launch.serving``).

Two sections, both on a fixed tiny arch + fixed seeds:

  * throughput/latency vs batch size — requests/sec, p50/p99 request
    latency and p50 decode-step wall per slot count, on a request
    stream with staggered lengths (so freed slots are re-claimed
    mid-run: real continuous batching, not a single lockstep wave);
  * adoption — the engine (``TMSNEngine`` + ``lm_sgd_worker``) trains
    the same tiny arch with a publisher attached, the recorded
    best-certificate snapshots are replayed into an
    :class:`~repro.launch.serving.AdoptionSlot` at fixed decode steps,
    and the server adopts them mid-stream. The zero-downtime claims are
    ASSERTED, not just reported: >= 2 adoptions, 0 dropped requests, 0
    recompiles after warm-up (jit cache sizes), plus the adoption-blip
    p99 step wall vs the steady-state p99 and the stale-vs-fresh
    certificate gap (``adopt_every=2``, so the server is measurably —
    boundedly — stale between probes).

Part of ``--tiny`` (the bench-smoke CI tier); ``serving.*`` guard
entries in ``check_regression.GUARDED`` WARN until the baseline is
regenerated with them.
"""

from __future__ import annotations

import numpy as np

import jax

from repro.core.engine import EngineConfig, TMSNEngine
from repro.core.sgd_worker import lm_sgd_worker
from repro.core.tmsn_sgd import TMSNSGDConfig
from repro.launch.serving import AdoptionSlot, ContinuousServer, Request, ServingConfig
from repro.models import init_params
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig

_ARCH = ArchConfig(
    name="bench-serving",
    arch_type="llama",
    num_layers=2,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    d_ff=64,
    vocab=128,
    remat=False,
    compute_dtype="float32",
)

_PROMPT = 8


def _requests(n: int, max_new: int, seed: int = 0) -> list[Request]:
    """Staggered request lengths (max_new, max_new-1, ..., >= 2) so
    completions free slots at different steps."""
    rng = np.random.default_rng(seed)
    return [
        Request(
            rid=i,
            prompt=rng.integers(0, _ARCH.vocab, _PROMPT).astype(np.int32),
            max_new=max(2, max_new - (i % 4)),
        )
        for i in range(n)
    ]


def _bench_throughput(quick: bool) -> list[str]:
    lines = []
    params = init_params(_ARCH, jax.random.PRNGKey(0))
    for slots in (2, 4) if quick else (2, 4, 8):
        scfg = ServingConfig(slots=slots, prompt_len=_PROMPT, max_new=8, seed=0)
        server = ContinuousServer(_ARCH, scfg, params)
        server.warmup()
        _, m = server.run(_requests(3 * slots, scfg.max_new))
        assert m["dropped_requests"] == 0 and m["recompiles"] == 0
        tag = f"serving.b{slots}"
        lines.append(f"{tag}.req_per_s,{m['req_per_s']:.1f},{m['requests_completed']}reqs")
        lines.append(f"{tag}.latency_p50_wall_ms,{m['latency_p50_s'] * 1e3:.2f},")
        lines.append(f"{tag}.latency_p99_wall_ms,{m['latency_p99_s'] * 1e3:.2f},")
        lines.append(f"{tag}.step_p50_wall_ms,{m['step_p50_ms']:.2f},{m['decode_steps']}steps")
        lines.append(f"{tag}.decode_tok_per_s,{m['decode_tok_per_s']:.0f},")
    return lines


def _bench_adoption(quick: bool) -> list[str]:
    lines = []
    # --- train the tiny arch on the engine, recording every publish ---
    # the EMA-smoothed best certificate plateaus for stretches; 12
    # rounds yields 4 strict improvements (publishes) at this config
    rounds = 12 if quick else 24
    worker = lm_sgd_worker(
        _ARCH,
        AdamWConfig(lr=1e-2),
        TMSNSGDConfig(local_steps=2, ema=0.8, width_coef=1.0),
        batch_size=2,
        seq=16,
    )
    # an AdoptionSlot only keeps the newest snapshot; the replay below
    # wants every one, so record through a list-publisher instead
    class ListRecorder:
        def __init__(self) -> None:
            self.items: list[tuple] = []

        def publish(self, params, cert, round=0) -> None:
            self.items.append((params, float(cert), int(round)))

    rec = ListRecorder()
    eng = TMSNEngine(
        worker,
        EngineConfig(
            n_workers=4, eps=0.0, max_rounds=rounds, seed=0,
            record_history=False, publish_every_k=1,
            # one chunk per round: a publish opportunity at every round
            # boundary, so every certificate improvement is captured
            rounds_per_dispatch=1,
        ),
    )
    eng.attach_publisher(rec)
    eng.run()
    published = rec.items
    assert len(published) >= 3, f"engine published only {len(published)} snapshots"
    lines.append(f"serving.adopt.snapshots_published,{len(published)},{rounds}rounds")

    # --- serve while replaying the engine's publishes mid-stream ------
    slots = 4
    scfg = ServingConfig(
        slots=slots, prompt_len=_PROMPT, max_new=10, seed=0, adopt_every=2
    )
    server = ContinuousServer(_ARCH, scfg, published[0][0])
    server.warmup()
    slot = AdoptionSlot()
    # replay one engine snapshot every 3 decode steps; the run is long
    # enough (>= 3 waves of requests) to consume at least three
    schedule = {3 * (i + 1): snap for i, snap in enumerate(published[1:])}

    def hook(_server: ContinuousServer, step: int) -> None:
        snap = schedule.get(step)
        if snap is not None:
            slot.publish(*snap)

    _, m = server.run(_requests(3 * slots, scfg.max_new, seed=1), slot=slot, step_hook=hook)

    # the acceptance criteria, asserted — a bench run that serves a
    # torn/stalled/recompiling path FAILS instead of shipping numbers
    assert m["adoptions"] >= 2, f"expected >= 2 adoptions, got {m['adoptions']}"
    assert m["dropped_requests"] == 0, f"dropped {m['dropped_requests']} requests"
    assert m["recompiles"] == 0, f"{m['recompiles']} recompiles after warm-up"

    lines.append(f"serving.adopt.adoptions,{m['adoptions']},of{slot.publishes}published")
    lines.append(f"serving.adopt.dropped_requests,{m['dropped_requests']},asserted0")
    lines.append(f"serving.adopt.recompiles,{m['recompiles']},asserted0")
    lines.append(f"serving.adopt.blip_p99_wall_ms,{m['adoption_blip_p99_ms']:.2f},adoption-step")
    lines.append(f"serving.adopt.steady_p99_wall_ms,{m['steady_step_p99_ms']:.2f},non-adoption")
    lines.append(f"serving.adopt.stale_cert_gap_mean,{m['stale_cert_gap_mean']:.6f},adopt_every=2")
    lines.append(f"serving.adopt.stale_cert_gap_max,{m['stale_cert_gap_max']:.6f},bounded-staleness")
    return lines


def run(quick: bool = False) -> list[str]:
    return _bench_throughput(quick) + _bench_adoption(quick)


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
