"""TMSN-SGD on the engine substrate: the transformer worker
(``repro.core.sgd_worker``) driven by ``TMSNEngine``, measured against
the simulator-fidelity oracle (``repro.core.tmsn_sgd.oracle_run``).

Claims checked (all on a fixed tiny arch + fixed seeds, so the protocol
metrics are deterministic across commits):

  * the engine-hosted run reaches a fixed fraction of the oracle's
    certificate descent in a guarded number of rounds
    (``engine_rounds_to_target`` — the target is derived FROM the
    oracle history, so it re-anchors automatically if model/optimizer
    numerics shift);
  * gossip stays payload-shaped: ``engine_bytes_broadcast`` counts only
    strict-improvement broadcasts at the eval_shape-derived
    ``payload_bytes`` (the worker defines no hand value);
  * the engine is faithful: final certificate gap to the oracle at the
    stop round (``oracle_cert_gap``, expected 0.0) and per-worker
    certificate monotonicity;
  * per-round collective bytes vs sync-DP's K gradient all-reduces on
    the production mesh (from dry-run records, when present).

Part of ``--tiny`` (the bench-smoke CI tier): guard entries for the
two protocol metrics live in ``check_regression.GUARDED`` and WARN
until the baseline is regenerated with them.
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.core.engine import EngineConfig, TMSNEngine
from repro.core.sgd_worker import lm_sgd_worker
from repro.core.tmsn_sgd import TMSNSGDConfig, oracle_run
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

_ARCH = ArchConfig(
    name="bench-tmsn-sgd",
    arch_type="llama",
    num_layers=2,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    d_ff=64,
    vocab=128,
    remat=False,
    compute_dtype="float32",
)


def run(quick: bool = False) -> list[str]:
    lines = []
    W, K = 4, 2
    rounds = 6 if quick else 12
    worker = lm_sgd_worker(
        _ARCH,
        AdamWConfig(lr=1e-2),
        TMSNSGDConfig(local_steps=K, ema=0.8, width_coef=1.0),
        batch_size=2,
        seq=16,
    )

    # --- oracle pass: fixes the descent target for this commit --------
    orc = oracle_run(worker, W, rounds, eps=0.0, seed=0)
    c0 = float(np.min(orc.history[0]))
    c1 = float(np.min(orc.history[-1]))
    # 75% of the oracle's descent — reachable well before the round
    # budget, so rounds_to_target measures protocol efficiency, not the
    # budget itself
    target = c1 + 0.25 * (c0 - c1)

    # --- engine-hosted run to that target -----------------------------
    eng = TMSNEngine(
        worker,
        EngineConfig(
            n_workers=W,
            eps=0.0,
            max_rounds=rounds,
            delay_rounds=1,
            seed=0,
            target_certificate=target,
        ),
    )
    res = eng.run()

    lines.append(f"tmsn_sgd.engine_rounds_to_target,{res.rounds},target={target:.4f}")
    lines.append(
        f"tmsn_sgd.engine_bytes_broadcast,{res.bytes_broadcast},"
        f"{res.messages_sent}msgs"
    )
    lines.append(f"tmsn_sgd.payload_bytes,{eng._payload_bytes},eval_shape-derived")

    # fidelity: engine's best certificate vs the oracle's at the SAME
    # round (bit-identical substrates => 0.0)
    gap = abs(
        float(np.min(res.final_certificates))
        - float(np.min(orc.history[res.rounds - 1]))
    )
    lines.append(f"tmsn_sgd.oracle_cert_gap,{gap:.6f},engine-vs-oracle")

    per_worker: dict[int, float] = {}
    mono = True
    for _, wid, cert in res.history:
        prev = per_worker.get(wid)
        if prev is not None and cert > prev + 1e-7:
            mono = False
        per_worker[wid] = cert
    lines.append(f"tmsn_sgd.certs_monotone,{int(mono)},bool")

    # --- production-mesh collective contrast (from dry-run records) ---
    for arch in ("yi_9b", "internlm2_20b"):
        base = os.path.join(DRYRUN_DIR, f"{arch}_train_4k_16x16.json")
        tm = os.path.join(DRYRUN_DIR, f"{arch}_train_4k_16x16_tmsn.json")
        if os.path.exists(base) and os.path.exists(tm):
            rb = json.load(open(base))
            rt = json.load(open(tm))
            if rb.get("status") == "ok" and rt.get("status") == "ok":
                cb = sum(rb["collective_bytes"].values()) * 4  # 4 sync steps
                ct = sum(rt["collective_bytes"].values())  # 1 round = 4 local steps
                lines.append(
                    f"tmsn_sgd.coll_bytes_ratio_{arch},{cb/max(ct,1):.2f},sync4steps/tmsn_round"
                )
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
