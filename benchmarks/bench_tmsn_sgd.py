"""TMSN-SGD (beyond-paper): reduced-config CPU training comparison of
synchronous data parallelism vs the TMSN strategy, plus the
collective-bytes contrast pulled from the dry-run records when present.

Claims checked:
  * TMSN-SGD trains (loss decreases) with W workers exchanging params
    only at round boundaries;
  * certificates are monotone non-increasing per worker;
  * per-round collective bytes ~= params-size vs sync-DP's K gradient
    all-reduces (from dryrun records, production mesh).
"""

from __future__ import annotations

import json
import os

import jax

from repro.configs import get_config, reduced
from repro.core.tmsn_sgd import TMSNSGDConfig, init_tmsn_state, make_tmsn_round
from repro.data.tokens import synthetic_token_batch
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import AdamWConfig, init_opt_state

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")


def run(quick: bool = False) -> list[str]:
    lines = []
    cfg = reduced(get_config("yi-9b"))
    opt_cfg = AdamWConfig(lr=1e-3)
    key = jax.random.PRNGKey(0)
    W, K, rounds = 4, 4, (4 if quick else 10)
    b, s = 4, 64

    # --- sync baseline ---
    params = init_params(cfg, key)
    opt = init_opt_state(params, opt_cfg)
    step = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    kb = key
    sync_losses = []
    for i in range(rounds * K):
        kb = jax.random.fold_in(kb, i)
        batch = synthetic_token_batch(kb, b * W, s, cfg.vocab)
        params, opt, m = step(params, opt, batch)
        sync_losses.append(float(m["loss"]))

    # --- TMSN-SGD ---
    tcfg = TMSNSGDConfig(num_workers=W, local_steps=K, eps=0.0)
    params_w, opt_w, cert_w = init_tmsn_state(cfg, opt_cfg, tcfg, key)
    round_fn = jax.jit(make_tmsn_round(cfg, opt_cfg, tcfg), donate_argnums=(0, 1))
    kb = jax.random.fold_in(key, 999)
    tmsn_losses = []
    certs_hist = []
    for r in range(rounds):
        kb = jax.random.fold_in(kb, r)
        batch = synthetic_token_batch(kb, W * K * b, s, cfg.vocab)
        batch_w = {k: v.reshape((W, K, b) + v.shape[1:]) for k, v in batch.items()}
        params_w, opt_w, cert_w, loss = round_fn(params_w, opt_w, cert_w, batch_w)
        tmsn_losses.append(float(loss))
        certs_hist.append([float(c) for c in cert_w])

    lines.append(f"tmsn_sgd.sync_final_loss,{sync_losses[-1]:.4f},start={sync_losses[0]:.4f}")
    lines.append(f"tmsn_sgd.tmsn_final_loss,{tmsn_losses[-1]:.4f},start={tmsn_losses[0]:.4f}")
    improved = tmsn_losses[-1] < tmsn_losses[0]
    lines.append(f"tmsn_sgd.tmsn_loss_improves,{int(improved)},bool")
    # cert monotonicity after warmup round (EMA from sentinel)
    mono = all(
        certs_hist[i + 1][w] <= certs_hist[i][w] + 1e-3
        for i in range(1, len(certs_hist) - 1)
        for w in range(W)
    )
    lines.append(f"tmsn_sgd.certs_monotone,{int(mono)},bool")

    # --- production-mesh collective contrast (from dry-run records) ---
    for arch in ("yi_9b", "internlm2_20b"):
        base = os.path.join(DRYRUN_DIR, f"{arch}_train_4k_16x16.json")
        tm = os.path.join(DRYRUN_DIR, f"{arch}_train_4k_16x16_tmsn.json")
        if os.path.exists(base) and os.path.exists(tm):
            rb = json.load(open(base))
            rt = json.load(open(tm))
            if rb.get("status") == "ok" and rt.get("status") == "ok":
                cb = sum(rb["collective_bytes"].values()) * 4  # 4 sync steps
                ct = sum(rt["collective_bytes"].values())  # 1 round = 4 local steps
                lines.append(
                    f"tmsn_sgd.coll_bytes_ratio_{arch},{cb/max(ct,1):.2f},sync4steps/tmsn_round"
                )
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
