"""Benchmark entry point — one module per paper table/figure plus the
framework-level benches. Prints ``name,value,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--full|--tiny] [--json out.json]

(--full runs the paper-scale sizes; default is the quick profile so the
suite completes on the CPU container; --tiny runs only the
minutes-not-hours benches — the every-push ``bench-smoke`` CI tier that
keeps a results artifact on every commit. --json additionally writes
the collected ``{name: value}`` dict as machine-readable JSON — the
format CI artifacts and the BENCH_*.json trajectory share. The JSON
carries a ``_schema`` entry with a format version, the machine shape
(device count, backend), the bench profile, and the git SHA the run
measured, so the regression guard and trajectory plots can key on
comparable runs; metric keys never start with ``_``.)
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

#: the --tiny selection: benches that finish in ~seconds on a 2-core
#: runner (still real measurements — stopping rule, kernel microbench,
#: protocol counters, the chaos resilience section, the serving tier's
#: continuous-batching + adoption run) so every push gets a comparable
#: JSON artifact
TINY_BENCHES = ["stopping", "kernels", "protocol", "tmsn_sgd", "chaos", "serving"]


def _git_sha() -> str | None:
    """SHA of the tree the numbers were measured on (None outside git —
    e.g. a source tarball; the artifact is still valid, just unpinned)."""
    try:
        proc = subprocess.run(
            ["git", "rev-parse", "HEAD"],
            capture_output=True,
            text=True,
            cwd=os.path.dirname(os.path.abspath(__file__)),
            timeout=10,
        )
    except (OSError, subprocess.TimeoutExpired):
        return None
    sha = proc.stdout.strip()
    return sha if proc.returncode == 0 and sha else None


def _parse_value(raw: str):
    try:
        return float(raw)
    except ValueError:
        return raw


def collect(selected: list[str], benches: dict, quick: bool) -> tuple[dict, int]:
    """Run the selected benches, printing (flushed) each CSV line as it
    is produced — a hung bench still leaves partial output in CI logs.
    Returns (results_dict, failures)."""
    results: dict = {}
    failures = 0

    def _emit(line: str) -> None:
        print(line, flush=True)

    for name in selected:
        t0 = time.time()
        try:
            for line in benches[name](quick=quick):
                _emit(line)
                parts = line.split(",")
                if len(parts) >= 2:
                    results[parts[0]] = _parse_value(parts[1])
            wall = time.time() - t0
            _emit(f"bench.{name}.wall_s,{wall:.1f},")
            results[f"bench.{name}.wall_s"] = round(wall, 1)
        except Exception as e:  # noqa: BLE001
            failures += 1
            _emit(f"bench.{name}.FAILED,{type(e).__name__},{e}")
            results[f"bench.{name}.FAILED"] = type(e).__name__
    return results, failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument(
        "--tiny", action="store_true",
        help=f"run only the fast benches ({','.join(TINY_BENCHES)}) — the "
        "every-push bench-smoke profile",
    )
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    ap.add_argument(
        "--json", default=None, metavar="OUT", help="also write {name: value} JSON here"
    )
    args = ap.parse_args()
    if args.full and args.tiny:
        ap.error("--full and --tiny are mutually exclusive")
    quick = not args.full

    from benchmarks import (
        bench_convergence,
        bench_kernels,
        bench_protocol,
        bench_scaling,
        bench_stopping,
    )

    benches = {
        "stopping": bench_stopping.run,
        "kernels": bench_kernels.run,
        "protocol": bench_protocol.run,
        "convergence": bench_convergence.run,
        "scaling": bench_scaling.run,
        "chaos": bench_scaling.run_chaos,
    }
    try:
        from benchmarks import bench_tmsn_sgd

        benches["tmsn_sgd"] = bench_tmsn_sgd.run
    except ImportError:
        pass
    try:
        from benchmarks import bench_ablations

        benches["ablations"] = bench_ablations.run
    except ImportError:
        pass
    try:
        from benchmarks import bench_serving

        benches["serving"] = bench_serving.run
    except ImportError:
        pass

    if args.only:
        selected = args.only.split(",")
    elif args.tiny:
        selected = list(TINY_BENCHES)
    else:
        selected = list(benches)
    print("name,value,derived", flush=True)
    results, failures = collect(selected, benches, quick)
    if args.json:
        import jax

        payload = {
            "_schema": {
                "version": 3,
                "devices": jax.device_count(),
                "backend": jax.default_backend(),
                "profile": "full" if args.full else ("tiny" if args.tiny else "quick"),
                "git_sha": _git_sha(),
            },
            **results,
        }
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True)
        print(f"# wrote {len(results)} results to {args.json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
