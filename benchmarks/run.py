"""Benchmark entry point — one module per paper table/figure plus the
framework-level benches. Prints ``name,value,derived`` CSV lines.

  PYTHONPATH=src python -m benchmarks.run [--full]

(--full runs the paper-scale sizes; default is the quick profile so the
suite completes on the CPU container.)
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--only", default=None, help="comma-separated bench names")
    args = ap.parse_args()
    quick = not args.full

    from benchmarks import bench_convergence, bench_kernels, bench_protocol, bench_stopping

    benches = {
        "stopping": bench_stopping.run,
        "kernels": bench_kernels.run,
        "protocol": bench_protocol.run,
        "convergence": bench_convergence.run,
    }
    try:
        from benchmarks import bench_tmsn_sgd

        benches["tmsn_sgd"] = bench_tmsn_sgd.run
    except ImportError:
        pass
    try:
        from benchmarks import bench_ablations

        benches["ablations"] = bench_ablations.run
    except ImportError:
        pass

    selected = args.only.split(",") if args.only else list(benches)
    print("name,value,derived")
    failures = 0
    for name in selected:
        t0 = time.time()
        try:
            for line in benches[name](quick=quick):
                print(line, flush=True)
            print(f"bench.{name}.wall_s,{time.time()-t0:.1f},", flush=True)
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"bench.{name}.FAILED,{type(e).__name__},{e}", flush=True)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
