"""Paper Table 1 + Figures 3/4 analogue: time-to-target-loss for
Sparrow (1 and 10 workers) vs XGBoost-like exact greedy vs
LightGBM-like GOSS on the synthetic splice-site analogue.

Cost model (mirrors the paper's hardware setting):
  * reading one example from the in-memory working set: MEM = 0.25
  * reading one example from disk-resident data:        DISK = 1.0
  * one incremental stump eval:                         0.1 x read
The in-memory baselines (the paper's x1e.xlarge rows) scan all n from
RAM each round; Sparrow scans its m-example sample from RAM and pays
DISK for each Sampler pass over the full set (the paper's c3.xlarge
disk setting); the off-memory baseline streams all n from disk per round.
Simulated seconds = cost units / worker speed (core/simulator.py).
"""

from __future__ import annotations

import json
import os

import numpy as np

from repro.boosting import (
    BoosterConfig,
    SparrowConfig,
    SparrowWorker,
    train_exact_greedy,
    train_goss,
)
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import exp_loss
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split

RESULTS = os.path.join(os.path.dirname(__file__), "results")
MEM, DISK = 0.25, 1.0


def _sparrow_curve(xtr, ytr, xte, yte, n_workers, max_events, seed=0, parallel_sampler=False):
    cfg = SparrowConfig(
        # paper Table 1: "TMSN, sample 10%"
        sample_size=max(xtr.shape[0] // 10, 2048),
        capacity=512,
        scanner=ScannerConfig(chunk_size=512, num_bins=8, gamma0=0.25),
        n_workers=n_workers,
        mem_read_cost=MEM,
        disk_read_cost=DISK,
        parallel_sampler=parallel_sampler,
    )
    worker = SparrowWorker(xtr, ytr, cfg)
    sim = TMSNSimulator(
        worker,
        [WorkerSpec(speed=1.0) for _ in range(n_workers)],
        SimulatorConfig(
            # eps=0: accept any strict improvement. A positive gap
            # deadlocks feature-partitioned workers once per-fire deltas
            # shrink below it (measured; EXPERIMENTS.md §Repro).
            n_workers=n_workers, max_events=max_events, seed=seed, eps=0.0,
            snapshot_every=max(max_events // 30, 1),
        ),
    )
    res = sim.run()
    curve = [(t, float(exp_loss(m, xte, yte))) for t, _, m in res.snapshots]
    best = int(np.argmin(res.final_certificates))
    curve.append((res.sim_time, float(exp_loss(res.final_models[best], xte, yte))))
    return curve, res


def _time_to(curve, target):
    best = float("inf")
    for t, loss in curve:
        best = min(best, loss)
        if best <= target:
            return t
    return float("nan")


def run(quick: bool = False) -> list[str]:
    lines = []
    n = 60_000 if quick else 150_000
    xb, y, _ = make_splice_like(SpliceConfig(n=n, d=48, num_bins=8, seed=0))
    xtr, ytr, xte, yte = train_test_split(xb, y)
    eval_fn = lambda m: float(exp_loss(m, xte, yte))

    rounds = 50 if quick else 90
    bc = BoosterConfig(num_rounds=rounds, num_bins=8, eval_every=3)
    tr_xgb = train_exact_greedy(xtr, ytr, bc, eval_fn)
    tr_goss = train_goss(xtr, ytr, bc, eval_fn)

    # in-memory baselines: all reads priced MEM; off-memory: DISK
    xgb_mem = [(c * MEM, loss) for c, loss in zip(tr_xgb.cost, tr_xgb.metric)]
    xgb_disk = [(c * DISK, loss) for c, loss in zip(tr_xgb.cost, tr_xgb.metric)]
    goss_mem = [(c * MEM, loss) for c, loss in zip(tr_goss.cost, tr_goss.metric)]

    ev = 1200 if quick else 5000
    s1_curve, s1 = _sparrow_curve(xtr, ytr, xte, yte, 1, ev)
    sN_curve, sN = _sparrow_curve(xtr, ytr, xte, yte, 10, ev * 4)
    sP_curve, sP = _sparrow_curve(xtr, ytr, xte, yte, 10, ev * 4, parallel_sampler=True)

    # Report time-to-loss at three levels: Sparrow leads in the early/mid
    # regime (the paper's operating point at 50M examples, where one
    # baseline full scan >> one certified stump); at this bench's small n
    # the exact-greedy tail catches up — a scale effect, discussed in
    # EXPERIMENTS.md. Sparrow's final loss sits slightly above the
    # exact-greedy floor, faithfully reproducing the paper's own Fig. 4
    # observation ("baffling" slightly-worse AUPRC).
    floor = max(min(loss for _, loss in xgb_mem), min(loss for _, loss in s1_curve))
    targets = {"early": 0.70, "mid": 0.64, "late": round(floor * 1.02, 4)}

    systems = {
        "xgboost_like_inmem": xgb_mem,
        "xgboost_like_offmem": xgb_disk,
        "lightgbm_like_goss_inmem": goss_mem,
        "sparrow_1worker_disk": s1_curve,
        "sparrow_10workers_disk": sN_curve,
        "sparrow_10w_parallel_sampler": sP_curve,
    }
    target = targets["late"]
    rows = {
        name: (_time_to(curve, target), min(loss for _, loss in curve))
        for name, curve in systems.items()
    }
    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "convergence.json"), "w") as f:
        json.dump(
            {
                "target_loss": target,
                "rows": {k: {"time": v[0], "final_loss": v[1]} for k, v in rows.items()},
                "curves": {
                    "xgb_mem": xgb_mem, "goss_mem": goss_mem,
                    "sparrow_1": s1_curve, "sparrow_10": sN_curve,
                    "sparrow_10_parallel": sP_curve,
                },
                "sparrow_msgs": {"sent": sN.messages_sent, "accepted": sN.messages_accepted},
            },
            f, indent=1, default=float,
        )
    for name, (t, loss) in rows.items():
        lines.append(f"convergence.{name},{t:.0f},final_loss={loss:.4f}")
    for lvl, tg in targets.items():
        tx = _time_to(xgb_mem, tg)
        ts = _time_to(sN_curve, tg)
        if tx == tx and ts == ts:
            lines.append(f"convergence.speedup10w_vs_xgbmem_at_{lvl},{tx / ts:.2f},loss<={tg}")
    t_s1 = _time_to(s1_curve, targets['mid'])
    t_sN = _time_to(sN_curve, targets['mid'])
    if t_s1 == t_s1 and t_sN == t_sN:
        lines.append(f"convergence.speedup_10w_vs_1w_mid,{t_s1 / t_sN:.2f},paper_claims_3.2x")
    t_sP = _time_to(sP_curve, targets['late'])
    t_sN2 = _time_to(sN_curve, targets['late'])
    if t_sP == t_sP and t_sN2 == t_sN2:
        lines.append(f"convergence.parallel_sampler_speedup,{t_sN2 / t_sP:.2f},beyond_paper")
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
