"""Render the §Roofline table from the dry-run JSON records.

  PYTHONPATH=src python -m benchmarks.roofline [--dir benchmarks/results/dryrun]
"""

from __future__ import annotations

import argparse
import glob
import json
import os

DEFAULT_DIR = os.path.join(os.path.dirname(__file__), "results", "dryrun")

MOVE_HINTS = {
    "compute_s": "raise arithmetic intensity: fuse, larger per-chip batch, or shard less",
    "memory_s": "cut HBM traffic: bf16 states, windowed KV caches, fused SSD mask, less remat",
    "collective_s": "cut exchanged bytes: TMSN-SGD rounds, 1D-instead-of-2D sharding, overlap",
}


def load(dir_: str) -> list[dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dir_, "*.json"))):
        with open(p) as f:
            recs.append(json.load(f))
    return recs


def fmt_row(r: dict) -> str:
    if r.get("status") == "skip":
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']}{' TMSN' if r.get('tmsn') else ''} | "
            f"SKIP | — | — | — | — | {r['reason'][:60]}... |"
        )
    if r.get("status") != "ok":
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ERROR "
            f"| — | — | — | — | {r.get('error', '')[:60]} |"
        )
    t = r["terms"]
    dom = r["dominant"].replace("_s", "")
    # argument+output = resident per-device bytes (reliable); temp is the
    # CPU backend's buffer liveness and over-states a TPU's (reported in
    # the JSON, not gated here).
    args_gb = r["memory"].get("argument_size_in_bytes", 0) / 1e9
    fits = "Y" if args_gb <= 16.0 else "OVER"
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']}{' TMSN' if r.get('tmsn') else ''} | "
        f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | {t['collective_s']:.2e} | "
        f"**{dom}** | {r['useful_ratio']:.2f} | {args_gb:.1f}GB/{fits} |"
    )


def render(recs: list[dict]) -> str:
    lines = [
        "| arch | shape | mesh | compute (s) | memory (s) | collective (s) "
        "| bottleneck | useful-FLOP ratio | bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in recs:
        lines.append(fmt_row(r))
    return "\n".join(lines)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DEFAULT_DIR)
    args = ap.parse_args()
    recs = load(args.dir)
    print(render(recs))
    n_ok = sum(1 for r in recs if r.get("status") == "ok")
    n_skip = sum(1 for r in recs if r.get("status") == "skip")
    n_err = len(recs) - n_ok - n_skip
    print(f"\n{n_ok} ok, {n_skip} skipped (documented), {n_err} errors")


if __name__ == "__main__":
    main()
