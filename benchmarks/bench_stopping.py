"""Stopping-rule benchmarks (paper §3): tightness of the
iterated-logarithm rule vs a union-bound Hoeffding rule (examples
needed to certify a true edge), soundness under the null, and the
n_eff / resampling dynamics the Sampler depends on."""

from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core.ess import effective_sample_size
from repro.core.stopping import (
    StoppingRuleParams,
    hoeffding_threshold,
    stopping_threshold,
)

RESULTS = os.path.join(os.path.dirname(__file__), "results")


def examples_to_fire(rule: str, corr: float, gamma: float, trials: int, horizon: int, seed: int):
    rng = np.random.default_rng(seed)
    p = StoppingRuleParams(C=1.0, delta=1e-3)
    fires = []
    for _ in range(trials):
        x = rng.choice([-1.0, 1.0], p=[(1 - corr) / 2, (1 + corr) / 2], size=horizon)
        m = np.cumsum(x)
        W = np.arange(1, horizon + 1, dtype=np.float64)
        M = m - 2 * gamma * W
        if rule == "il":
            thr = np.asarray(stopping_threshold(jnp.asarray(W, jnp.float32), jnp.asarray(M, jnp.float32), p))
        else:
            thr = np.asarray(hoeffding_threshold(jnp.asarray(W, jnp.float32), jnp.asarray(W, jnp.float32), p))
        idx = np.flatnonzero(M > thr)
        fires.append(int(idx[0]) if idx.size else horizon)
    return float(np.mean(fires))


def run(quick: bool = False) -> list[str]:
    lines = []
    trials = 30 if quick else 100
    horizon = 20_000
    out = {}
    for corr, gamma in [(0.4, 0.1), (0.2, 0.05), (0.1, 0.02)]:
        il = examples_to_fire("il", corr, gamma, trials, horizon, 0)
        hf = examples_to_fire("hoeffding", corr, gamma, trials, horizon, 0)
        out[f"corr{corr}"] = {"il": il, "hoeffding": hf}
        lines.append(f"stopping.examples_to_fire_il_corr{corr},{il:.0f},hoeffding={hf:.0f}")

    # soundness: false-certification rate under the null at delta=1e-2
    rng = np.random.default_rng(1)
    p = StoppingRuleParams(C=1.0, delta=1e-2)
    gamma = 0.05
    false = 0
    n_null = 200 if quick else 500
    for _ in range(n_null):
        x = rng.choice([-1.0, 1.0], size=4000)
        m = np.cumsum(x)
        W = np.arange(1, 4001, dtype=np.float64)
        M = m - 2 * gamma * W
        thr = np.asarray(stopping_threshold(jnp.asarray(W, jnp.float32), jnp.asarray(M, jnp.float32), p))
        false += bool(np.any(M > thr))
    out["false_rate"] = false / n_null
    lines.append(f"stopping.false_cert_rate,{false / n_null:.4f},delta=1e-2")

    # n_eff decay under boosting-like weight skew
    w = np.ones(10_000)
    decay = []
    rng = np.random.default_rng(2)
    for step in range(6):
        decay.append(float(effective_sample_size(jnp.asarray(w))) / 10_000)
        w *= np.exp(rng.normal(0, 0.5, size=w.shape))  # one boosting round's skew
    out["ess_decay"] = decay
    lines.append(f"stopping.ess_after_5_rounds,{decay[-1]:.4f},fraction_of_m")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "stopping.json"), "w") as f:
        json.dump(out, f, indent=1, default=float)
    return lines


if __name__ == "__main__":
    for line in run(quick=True):
        print(line)
