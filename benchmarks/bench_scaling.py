"""Worker-count scaling of the round-based TMSN engine (the paper's
headline regime: hundreds of independent machines).

Sweeps W ∈ {8, 32, 128, 256} (quick profile stops at 128) and reports,
per W:

  * ``rounds_to_target``   — gossip efficiency (should NOT grow with W;
    more workers means more parallel exploration of the feature space),
  * ``wall_ms_per_round``  — engine throughput: one round advances all W
    workers one segment inside a single jitted computation, so this
    should grow far sublinearly in W,
  * ``per_segment_us``     — wall per worker-segment (the number that
    collapses for the event-driven simulator past ~16 workers).

At W=8 the event simulator runs the same workload for a direct
per-segment speedup ratio (`engine_speedup_vs_sim`).

The *dispatch* section reruns W=128 with
``rounds_per_dispatch ∈ {1, 8, 32}``: one jitted ``lax.scan`` chunk per
dispatch instead of one Python dispatch + host sync per round — the
wall/round at chunk 1 vs 8 is the measured dispatch overhead.

The *sharded* section sweeps W ∈ {64, 256, 1024} through the
shard-mapped engine on 8 forced host devices (each sweep point is a
subprocess so ``XLA_FLAGS=--xla_force_host_platform_device_count`` is
set before the child's first jax import) and reports per-round wall
clock plus gossip bytes/round — the all_gather footprint that would hit
a real interconnect (plus a derived lower-bound ICI-link wire time).
W ∈ {256, 1024} additionally run with ``gossip_mode="gated"``: payloads
move only for each device's top-k improved candidates, and the parent
checks the final certificates stay IDENTICAL to dense (uniform delay)
while gossip bytes/round collapse. It measures substrate throughput and
traffic, not convergence: at W > d some workers own no features (the
paper regime d >= W is what the single-device sweep above covers).

The *sparse* section reruns the sharded sweep with
``inflight_capacity=64``: bounded per-destination pending queues plus
the fused ``kernels/round_step.py`` delivery kernel instead of the dense
``(W_local, W, D)`` in-flight buffer. At uniform delay the end state
must stay digest-identical to dense (worst-first eviction preserves the
per-round delivery argmin), and the wall/round is reported against both
the committed baseline and the same-run dense number — on the Sparrow
workload that wall is worker-compute-bound (per_segment_us is flat
across W), so the representation barely moves it. The wall-time claim
therefore gets its own *round-machinery isolation* pair: a
trivial-segment worker (``_RoundOnlyWorker``) at delay depth 256, where
the dense per-shard ``(W/n_dev, W, 256)`` buffer shift IS the per-round
cost, run dense-vs-sparse on the same profile in the same bench — the
sparse queue must be >= 2x faster per round (measured ~12x on an 8-way
CPU host) or the bench fails loudly. A heterogeneous
delay profile (``het32``: frozen link delays in [1, 32]) then measures
the small-capacity approximation gap dense-vs-sparse — reported, never
assumed away. Finally W=4096 with ``het64`` delays runs BOTH paths
under a hard address-space cap (RLIMIT_AS): the dense buffer alone
(512 x 4096 x 64 f32 per shard, plus its shift copy) exceeds the cap,
so dense must die while sparse completes (dense's in-flight state is a
single 4 GiB allocation before its shift copy; sparse peaks well under
the cap) — the bench fails loudly if dense unexpectedly fits. A
roofline accounting of the fused kernel
(launch/hlo_analysis.round_step_roofline) closes the section.

The *control-plane* section sweeps W ∈ {4096, 10240} (toy worker,
gated gossip, capacity 64, uniform delay, the same 9 GiB RLIMIT_AS cap)
with ``control_plane`` dense vs sparse: dense ships W·5 B of
certs+flags every round, sparse only each device's top-k
(cert, global id, round) triples at 12 B each. Under uniform delay the
end state must stay digest-identical (the suppressed-runner-up argument
in docs/architecture.md) and at W=10240 the per-round control bytes
must collapse >= 10x — both enforced loudly. A het-delay pair at
W=4096 then measures (reports, never asserts) the sparse-control
approximation gap, exactly like the gated-gossip and bounded-queue
sections above.

The *pod* section runs W=256 on a hierarchical (2, 4) ``(pod, workers)``
mesh and reports the two interconnect tiers separately — intra-pod
all_gather bytes/round (ICI) vs amortized cross-pod candidate-exchange
bytes/round (DCN) — at ``cross_pod_every_k ∈ {1, 8}``. k=1 must match
the flat 8-device engine bit-identically (certs digest, uniform delay;
a mismatch fails the bench); k=8 must cut amortized DCN bytes ≥ 5x,
and its certificate divergence from the flat run is *reported* as a
measured approximation gap, never assumed away.
"""

from __future__ import annotations

import json
import os
import subprocess
import sys
import time

import numpy as np

from repro.boosting import BatchedSparrowWorker, SparrowConfig, SparrowWorker
from repro.boosting.scanner import ScannerConfig
from repro.core.engine import EngineConfig, TMSNEngine
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split

RESULTS = os.path.join(os.path.dirname(__file__), "results")

TARGET_CERT = -0.06


def _data(quick: bool):
    n = 30_000 if quick else 60_000
    # d >= max sweep W: ownership assigns feature j to worker j mod W,
    # so d < W leaves workers >= d with zero features — they could never
    # fire and rounds_to_target at large W would be vacuous.
    d = 128 if quick else 256
    xb, y, _ = make_splice_like(SpliceConfig(n=n, d=d, num_bins=8, seed=11))
    xtr, ytr, _, _ = train_test_split(xb, y)
    return xtr, ytr


def _sparrow_cfg(w: int) -> SparrowConfig:
    return SparrowConfig(
        sample_size=1024,
        capacity=48,
        scanner=ScannerConfig(chunk_size=256, num_bins=8, gamma0=0.25),
        n_workers=w,
    )


def _run_engine(xtr, ytr, w: int, max_rounds: int) -> dict:
    worker = BatchedSparrowWorker(xtr, ytr, _sparrow_cfg(w))
    eng = TMSNEngine(
        worker,
        EngineConfig(
            n_workers=w,
            max_rounds=max_rounds,
            target_certificate=TARGET_CERT,
            seed=0,
            record_history=False,
            rounds_per_dispatch=8,  # explicit: baselines must not move with env overrides
        ),
    )
    res = eng.run()  # first run pays jit compilation
    t0 = time.time()
    res = eng.run()  # second run reuses the compiled round step
    wall = time.time() - t0
    out = {
        "rounds_to_target": res.rounds,
        "hit_target": min(res.final_certificates) <= TARGET_CERT,
        "best_cert": min(res.final_certificates),
        "wall_s": wall,
        "wall_ms_per_round": 1e3 * wall / max(res.rounds, 1),
        "per_segment_us": 1e6 * wall / max(res.rounds * w, 1),
        "messages_sent": res.messages_sent,
        "messages_accepted": res.messages_accepted,
    }
    return out


def _run_dispatch_chunk(xtr, ytr, w: int, rounds: int, rpd: int) -> dict:
    """Fixed-round throughput run (no target, no history: zero host
    syncs inside the loop) at a given rounds_per_dispatch."""
    worker = BatchedSparrowWorker(xtr, ytr, _sparrow_cfg(w))
    eng = TMSNEngine(
        worker,
        EngineConfig(
            n_workers=w,
            max_rounds=rounds,
            seed=0,
            record_history=False,
            rounds_per_dispatch=rpd,
        ),
    )
    eng.run()  # compile
    t0 = time.time()
    res = eng.run()
    wall = time.time() - t0
    return {
        "rounds_per_dispatch": rpd,
        "rounds": res.rounds,
        "wall_ms_per_round": 1e3 * wall / max(res.rounds, 1),
    }


SHARDED_DEVICES = 8


class _RoundOnlyWorker:
    """Trivial-segment worker for isolating the round machinery.

    Sparrow's per-worker segment costs ~2.5 ms of scan compute, so at
    W=1024 the end-to-end wall is worker-compute-bound and the in-flight
    representation is invisible in it. This worker's segment is O(1)
    (decrement a counter, maybe improve the certificate), so a run's
    wall is almost entirely the gossip + in-flight + delivery machinery
    — the thing the dense-buffer/sparse-queue comparison is about.
    Mirrors the shard-map worker contract: per-worker constants live in
    the state pytree.
    """

    def __init__(self, w: int):
        import jax.numpy as jnp

        self._period = jnp.asarray(1 + np.arange(w) % 4, jnp.int32)
        self._dec = jnp.asarray(0.01 + 0.001 * (np.arange(w) % 7), jnp.float32)

    def init_batch(self, n_workers, seed):
        import jax.numpy as jnp

        z = jnp.zeros((n_workers,), jnp.int32)
        return {
            "segs": z,
            "fires": z,
            "cert": jnp.zeros((n_workers,), jnp.float32),
            "owner": jnp.arange(n_workers, dtype=jnp.int32),
            "period": self._period,
            "dec": self._dec,
        }

    def scan_round(self, state, mask):
        import jax.numpy as jnp

        segs = state["segs"] + mask.astype(jnp.int32)
        fired = mask & (segs % state["period"] == 0)
        fires = state["fires"] + fired.astype(jnp.int32)
        own = -state["dec"] * fires
        cert = jnp.where(fired, jnp.minimum(state["cert"], own), state["cert"])
        new = dict(state, segs=segs, fires=fires, cert=cert)
        return new, mask.astype(jnp.float32), fired

    # no resample hooks: the engines detect their absence at build time
    # and statically drop the resample branch (repro.core.worker), so
    # the sweep measures the lean round path

    def certificates(self, state):
        return state["cert"]

    def export_models(self, state):
        return {"owner": state["owner"], "cert": state["cert"]}

    def adopt_batch(self, state, models, certs, take):
        import jax.numpy as jnp

        new = dict(state)
        new["cert"] = jnp.where(take, certs, state["cert"])
        return new, jnp.zeros(state["cert"].shape, jnp.float32)

    def payload_bytes(self):
        return 8


def _sharded_child(
    w: int,
    n_dev: int,
    rounds: int,
    gossip_mode: str,
    pods: int = 1,
    cross_k: int = 1,
    capacity: int = 0,
    delay_profile: str = "uniform",
    mem_gb: int = 0,
    worker_kind: str = "sparrow",
    control_plane: str = "dense",
    fault_spec: str = "",
    churn: int = 0,
) -> dict:
    """Runs inside the subprocess (forced host devices already in env):
    one shard-mapped engine run of ``rounds`` rounds, timed after a
    compile run, JSON result on stdout. ``pods > 1`` runs the
    hierarchical (pod, workers) mesh with the given cross-pod cadence.
    ``capacity > 0`` swaps the dense in-flight buffer for the sparse
    pending queue; ``delay_profile="hetD"`` freezes per-link delays in
    [1, D]; ``mem_gb > 0`` caps the child's address space (RLIMIT_AS) so
    the dense-path memory wall is a hard, reproducible failure instead
    of an allocator-dependent slowdown; ``worker_kind="toy"`` swaps the
    Sparrow worker for :class:`_RoundOnlyWorker` so the wall isolates
    the round machinery; ``control_plane="sparse"`` swaps the dense
    certs/flags control gather for top-k candidate triples;
    ``fault_spec`` injects a FaultPlan (same spec string as
    REPRO_FAULT_PLAN); ``churn = N`` reserves N spare slots and drives a
    churn trace — N spares join and N founding workers leave, spread
    evenly over the middle of the run."""
    import hashlib

    from repro.core.engine import EngineConfig, MembershipPlan, make_engine, quantize_latency
    from repro.launch.mesh import make_worker_mesh

    if mem_gb:
        import resource

        cap_bytes = mem_gb << 30
        resource.setrlimit(resource.RLIMIT_AS, (cap_bytes, cap_bytes))

    delay_rounds: object = 1
    if delay_profile.startswith("het"):
        # latencies in [0.01, 0.01 * depth) at dt=0.01 -> delays in [1, depth]
        depth = int(delay_profile[3:])
        delay_rounds = quantize_latency(0.01, 0.01 * (depth - 1), 0.01, w, seed=0)

    if worker_kind == "toy":
        worker: object = _RoundOnlyWorker(w)
    else:
        # scaled-down per-worker footprint so W=1024 fits a CPU host:
        # d=128 features, 256-example samples (throughput/traffic profile)
        xb, y, _ = make_splice_like(SpliceConfig(n=20_000, d=128, num_bins=8, seed=11))
        xtr, ytr, _, _ = train_test_split(xb, y)
        cfg = SparrowConfig(
            sample_size=256,
            capacity=32,
            scanner=ScannerConfig(chunk_size=128, num_bins=8, gamma0=0.25),
            n_workers=w,
        )
        worker = BatchedSparrowWorker(xtr, ytr, cfg)
    membership = None
    if churn:
        # churn trace: the top `churn` slots are spares that join at
        # rounds spread over [2, rounds - 2]; the first `churn` founding
        # workers leave over the same window (join + leave = fail-stop
        # composition, so the run must complete without deadlock)
        lo, hi = 2, max(3, rounds - 2)
        span = max(1, hi - lo)
        membership = MembershipPlan(
            joins=tuple(
                (lo + (i * span) // churn, w - churn + i) for i in range(churn)
            ),
            leaves=tuple((lo + (i * span) // churn, i) for i in range(churn)),
        )
    eng = make_engine(
        worker,
        EngineConfig(
            n_workers=w,
            max_rounds=rounds,
            seed=0,
            record_history=False,
            mesh=make_worker_mesh(n_dev, pods=pods),
            gossip_mode=gossip_mode,
            rounds_per_dispatch=8,  # explicit: baselines must not move with env
            cross_pod_every_k=cross_k,  # explicit, like rounds_per_dispatch
            cross_pod_top_k=1,
            inflight_capacity=capacity,
            delay_rounds=delay_rounds,
            control_plane=control_plane,
            fault_spec=fault_spec,  # explicit: "" pins chaos OFF despite env
            spare_slots=churn,
            membership=membership,
        ),
    )
    res = eng.run()  # compile
    t0 = time.time()
    res = eng.run()
    wall = time.time() - t0
    certs = np.asarray(res.final_certificates, np.float32)
    return {
        "w": w,
        "devices": n_dev,
        "pods": pods,
        "cross_pod_every_k": cross_k,
        "rounds": res.rounds,
        "gossip_mode": res.gossip_mode,
        "wall_ms_per_round": 1e3 * wall / max(res.rounds, 1),
        "per_segment_us": 1e6 * wall / max(res.rounds * w, 1),
        "gossip_bytes_per_round": res.gossip_bytes_per_round,
        "gossip_bytes_per_round_ici": res.gossip_bytes_per_round_ici,
        "gossip_bytes_per_round_dcn": res.gossip_bytes_per_round_dcn,
        "gossip_mb_total": res.gossip_bytes_per_round * res.rounds / 1e6,
        "messages_sent": res.messages_sent,
        "messages_sent_dcn": res.messages_sent_dcn,
        "messages_accepted": res.messages_accepted,
        "messages_evicted": res.messages_evicted,
        "inflight_capacity": capacity,
        "inflight_occupancy_peak": res.inflight_occupancy_peak,
        "control_plane": res.control_plane,
        "control_bytes_per_round": res.control_bytes_per_round,
        "messages_dropped_injected": res.messages_dropped_injected,
        "messages_corrupt_rejected": res.messages_corrupt_rejected,
        "workers_joined": res.workers_joined,
        "best_cert": min(res.final_certificates),
        # digest of ALL final certs so the parent can check dense/gated
        # end-state identity (uniform delay) without shipping W floats
        "certs_digest": hashlib.sha1(certs.tobytes()).hexdigest(),
    }


def _run_sharded(
    w: int,
    rounds: int,
    gossip_mode: str = "dense",
    pods: int = 1,
    cross_k: int = 1,
    capacity: int = 0,
    delay_profile: str = "uniform",
    mem_gb: int = 0,
    worker_kind: str = "sparrow",
    control_plane: str = "dense",
    fault_spec: str = "",
    churn: int = 0,
    check: bool = True,
    timeout: int = 3600,
) -> dict:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    # the forced device count only applies to the HOST platform — pin
    # the child to cpu so a machine with a real accelerator still runs
    # the 8-way host sweep instead of crashing on a 1-device GPU mesh
    env["JAX_PLATFORMS"] = "cpu"
    # appended AFTER any inherited flags: XLA flag parsing is last-wins,
    # so the child's forced device count must come last to stick
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={SHARDED_DEVICES}"
    ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in [os.path.join(root, "src"), env.get("PYTHONPATH", "")] if p
    )
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.bench_scaling",
             "--sharded-child", str(w), str(SHARDED_DEVICES), str(rounds), gossip_mode,
             str(pods), str(cross_k), str(capacity), delay_profile, str(mem_gb),
             worker_kind, control_plane, fault_spec, str(churn)],
            env=env,
            cwd=root,
            capture_output=True,
            text=True,
            timeout=timeout,
        )
    except subprocess.TimeoutExpired:
        # an address-space-capped child can wedge instead of dying (one
        # device thread OOMs inside a collective while the rest wait at
        # the rendezvous) — for expected-failure probes that is still
        # just "did not complete"
        if not check:
            return {"completed": False, "w": w, "mem_gb": mem_gb, "error_tail": "timeout"}
        raise
    if proc.returncode != 0:
        if not check:
            # expected-failure probe (the dense memory-wall attempt):
            # report what happened instead of raising
            return {
                "completed": False,
                "w": w,
                "mem_gb": mem_gb,
                "error_tail": (proc.stderr or proc.stdout)[-400:],
            }
        raise RuntimeError(
            f"sharded child W={w} ({gossip_mode}, pods={pods}, k={cross_k}, "
            f"capacity={capacity}, delay={delay_profile}, mem_gb={mem_gb}, "
            f"control={control_plane}, faults={fault_spec!r}, churn={churn}) failed:\n"
            f"{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}"
        )
    # the child prints exactly one JSON line last (jax may warn above it)
    res = json.loads(proc.stdout.strip().splitlines()[-1])
    res["completed"] = True
    return res


def run(quick: bool = False) -> list[str]:
    lines: list[str] = []
    out: dict = {}
    xtr, ytr = _data(quick)
    sweep = (8, 32, 128) if quick else (8, 32, 128, 256)
    max_rounds = 200 if quick else 400

    for w in sweep:
        res = _run_engine(xtr, ytr, w, max_rounds)
        out[f"w{w}"] = res
        lines.append(f"scaling.w{w}.rounds_to_target,{res['rounds_to_target']},cap_{max_rounds}")
        lines.append(f"scaling.w{w}.wall_ms_per_round,{res['wall_ms_per_round']:.1f},")
        lines.append(f"scaling.w{w}.per_segment_us,{res['per_segment_us']:.0f},")
        lines.append(f"scaling.w{w}.best_cert,{res['best_cert']:.4f},target_{TARGET_CERT}")

    # engine vs event-sim per-segment cost at a size the sim can still run
    w = 8
    worker = SparrowWorker(xtr, ytr, _sparrow_cfg(w))
    ev = 400 if quick else 1600
    sim = TMSNSimulator(
        worker,
        [WorkerSpec() for _ in range(w)],
        SimulatorConfig(n_workers=w, max_events=ev, seed=0),
    )
    sim.run()  # warm the per-segment jit caches
    t0 = time.time()
    res_sim = sim.run()
    sim_wall = time.time() - t0
    sim_us = 1e6 * sim_wall / max(res_sim.events_processed, 1)
    out["sim_w8"] = {"events": res_sim.events_processed, "per_event_us": sim_us}
    speedup = sim_us / max(out["w8"]["per_segment_us"], 1e-9)
    out["engine_speedup_vs_sim_w8"] = speedup
    lines.append(f"scaling.sim_w8.per_event_us,{sim_us:.0f},event_driven_oracle")
    lines.append(f"scaling.w8.engine_speedup_vs_sim,{speedup:.1f},per_segment_ratio")

    # --- dispatch-chunk sweep: wall/round vs rounds_per_dispatch ----------
    # >= 2 full chunks at the largest rpd, so every sweep point actually
    # measures its labeled chunk size (run() clamps a chunk to the
    # rounds remaining)
    w = 128
    disp_rounds = 64
    for rpd in (1, 8, 32):
        res = _run_dispatch_chunk(xtr, ytr, w, disp_rounds, rpd)
        out[f"dispatch_w{w}_rpd{rpd}"] = res
        lines.append(
            f"scaling.dispatch_w{w}_rpd{rpd}.wall_ms_per_round,"
            f"{res['wall_ms_per_round']:.1f},{disp_rounds}_rounds"
        )
    speedup = (
        out[f"dispatch_w{w}_rpd1"]["wall_ms_per_round"]
        / max(out[f"dispatch_w{w}_rpd8"]["wall_ms_per_round"], 1e-9)
    )
    out["dispatch_w128_speedup_rpd8_vs_rpd1"] = speedup
    lines.append(f"scaling.dispatch_w{w}.speedup_rpd8_vs_rpd1,{speedup:.2f},wall_ratio")

    # --- sharded engine sweep across forced host devices ------------------
    from repro.launch.mesh import ici_round_seconds

    rounds = 6 if quick else 20
    for w in (64, 256, 1024):
        res = _run_sharded(w, rounds)
        out[f"sharded_w{w}"] = res
        pre = f"scaling.sharded_w{w}"
        lines.append(f"{pre}.wall_ms_per_round,{res['wall_ms_per_round']:.1f},{SHARDED_DEVICES}_devices")
        lines.append(f"{pre}.per_segment_us,{res['per_segment_us']:.0f},")
        lines.append(f"{pre}.gossip_bytes_per_round,{res['gossip_bytes_per_round']},all_gather_footprint")
        lines.append(f"{pre}.messages_sent,{res['messages_sent']},{res['rounds']}_rounds")

    # gated gossip: payloads only for top-k improved candidates; end
    # state must stay identical to dense under the (uniform) delay here
    for w in (256, 1024):
        res = _run_sharded(w, rounds, gossip_mode="gated")
        out[f"sharded_w{w}_gated"] = res
        pre = f"scaling.sharded_w{w}_gated"
        dense = out[f"sharded_w{w}"]
        reduction = dense["gossip_bytes_per_round"] / max(res["gossip_bytes_per_round"], 1)
        identical = int(res["certs_digest"] == dense["certs_digest"])
        if not identical:
            # uniform delay: gated MUST reproduce dense exactly — a
            # mismatch is an equivalence regression, not noise, and has
            # to fail the bench (and with it the full CI tier) loudly
            raise RuntimeError(
                f"gated gossip diverged from dense at W={w} under uniform delay: "
                f"certs digest {res['certs_digest']} != {dense['certs_digest']}"
            )
        lines.append(f"{pre}.wall_ms_per_round,{res['wall_ms_per_round']:.1f},{SHARDED_DEVICES}_devices")
        lines.append(
            f"{pre}.gossip_bytes_per_round,{res['gossip_bytes_per_round']},"
            f"vs_{dense['gossip_bytes_per_round']}_dense"
        )
        lines.append(f"{pre}.gossip_reduction_x,{reduction:.1f},dense_over_gated")
        lines.append(f"{pre}.certs_identical_to_dense,{identical},uniform_delay")
        lines.append(
            f"{pre}.ici_us_per_round,{1e6 * ici_round_seconds(res['gossip_bytes_per_round']):.1f},"
            f"vs_{1e6 * ici_round_seconds(dense['gossip_bytes_per_round']):.1f}_dense"
        )

    # --- hierarchical (pod, workers) mesh: ICI vs DCN traffic tiers -------
    # W=256 on a (2, 4) pod mesh. cross_pod_every_k=1 must reproduce the
    # flat 8-device dense run bit-identically (uniform delay); k=8 is the
    # approximation regime — per-k certificate divergence is REPORTED
    # (measured, never assumed), while the amortized DCN footprint must
    # collapse ~k-fold.
    from repro.launch.mesh import dcn_round_seconds

    w = 256
    pod_sweep = {}
    for k in (1, 8):
        res = _run_sharded(w, rounds, gossip_mode="dense", pods=2, cross_k=k)
        pod_sweep[k] = res
        out[f"pod2_w{w}_k{k}"] = res
        pre = f"scaling.pod2_w{w}_k{k}"
        lines.append(f"{pre}.wall_ms_per_round,{res['wall_ms_per_round']:.1f},2x4_pod_mesh")
        lines.append(f"{pre}.ici_bytes_per_round,{res['gossip_bytes_per_round_ici']},intra_pod_all_gather")
        lines.append(f"{pre}.dcn_bytes_per_round,{res['gossip_bytes_per_round_dcn']},cross_pod_amortized")
        lines.append(f"{pre}.messages_sent_dcn,{res['messages_sent_dcn']},{res['rounds']}_rounds")
        lines.append(
            f"{pre}.dcn_us_per_round,{1e6 * dcn_round_seconds(res['gossip_bytes_per_round_dcn']):.1f},"
            f"derived_wire_time"
        )
    flat_dense = out[f"sharded_w{w}"]
    if pod_sweep[1]["certs_digest"] != flat_dense["certs_digest"]:
        # uniform delay + k=1: the pod mesh MUST reproduce the flat
        # engine exactly — a mismatch is an equivalence regression and
        # has to fail the bench (and with it the full CI tier) loudly
        raise RuntimeError(
            f"pod mesh diverged from the flat engine at W={w}, cross_pod_every_k=1: "
            f"certs digest {pod_sweep[1]['certs_digest']} != {flat_dense['certs_digest']}"
        )
    lines.append(f"scaling.pod2_w{w}_k1.certs_identical_to_flat,1,uniform_delay")
    dcn_drop = pod_sweep[1]["gossip_bytes_per_round_dcn"] / max(
        pod_sweep[8]["gossip_bytes_per_round_dcn"], 1
    )
    if dcn_drop < 5.0:
        raise RuntimeError(
            f"cross_pod_every_k=8 only cut amortized DCN bytes/round {dcn_drop:.1f}x "
            f"(expected >= 5x) at W={w}"
        )
    out[f"pod2_w{w}_dcn_reduction_k8_vs_k1"] = dcn_drop
    lines.append(f"scaling.pod2_w{w}_k8.dcn_reduction_x_vs_k1,{dcn_drop:.1f},amortized")
    # measured approximation gap, reported not asserted
    gap = abs(pod_sweep[8]["best_cert"] - flat_dense["best_cert"])
    out[f"pod2_w{w}_k8_best_cert_gap_vs_flat"] = gap
    lines.append(f"scaling.pod2_w{w}_k8.best_cert_gap_vs_flat,{gap:.5f},measured_divergence")

    # --- sparse in-flight state: pending queues + fused round kernel ------
    # (i) uniform delay, W=1024, C=64: worst-first eviction preserves the
    # per-round delivery argmin when every pending entry shares the same
    # due round, so the end state must be digest-IDENTICAL to the dense
    # run above — a mismatch is an equivalence regression and fails the
    # bench loudly. Wall/round is reported against both the committed
    # baseline and the same-run dense number (same machine, same noise).
    w, cap = 1024, 64
    res = _run_sharded(w, rounds, capacity=cap)
    out[f"sparse_w{w}"] = res
    dense = out[f"sharded_w{w}"]
    if res["certs_digest"] != dense["certs_digest"]:
        raise RuntimeError(
            f"sparse in-flight state diverged from dense at W={w} under uniform "
            f"delay: certs digest {res['certs_digest']} != {dense['certs_digest']}"
        )
    pre = f"scaling.sparse_w{w}"
    lines.append(f"{pre}.wall_ms_per_round,{res['wall_ms_per_round']:.1f},capacity_{cap}")
    lines.append(f"{pre}.certs_identical_to_dense,1,uniform_delay")
    lines.append(f"{pre}.inflight_occupancy_peak,{res['inflight_occupancy_peak']},capacity_{cap}")
    lines.append(f"{pre}.messages_evicted,{res['messages_evicted']},accounted_drops")
    same_run = dense["wall_ms_per_round"] / max(res["wall_ms_per_round"], 1e-9)
    out[f"sparse_w{w}_speedup_vs_same_run_dense"] = same_run
    lines.append(f"{pre}.speedup_vs_same_run_dense,{same_run:.2f},wall_ratio")
    base_path = os.path.join(os.path.dirname(os.path.abspath(__file__)), "baseline.json")
    if os.path.exists(base_path):
        with open(base_path) as f:
            base_ms = (
                json.load(f)
                .get("metrics", {})
                .get(f"scaling.sharded_w{w}.wall_ms_per_round", {})
                .get("value")
            )
        if base_ms:
            sp = base_ms / max(res["wall_ms_per_round"], 1e-9)
            out[f"sparse_w{w}_speedup_vs_baseline"] = sp
            lines.append(
                f"{pre}.speedup_vs_baseline,{sp:.2f},vs_committed_dense_{base_ms:g}ms"
            )

    # (ii) round-machinery isolation, W=1024, delays in [1, 256], 24
    # rounds: Sparrow's ~2.5 ms/worker segment makes the end-to-end wall
    # above worker-compute-bound (per_segment_us is flat across W), so
    # the in-flight representation cannot move it — the sparse win lives
    # where the round machinery IS the cost. A trivial-segment worker
    # (_RoundOnlyWorker) at delay depth 256 makes the dense per-shard
    # (W/n_dev, W, 256) f32 buffer (128 MiB/shard, shifted every round)
    # the dominant per-round cost; the sparse queue carries (W, C) x 16 B
    # regardless of depth. This ratio is the headline wall-ms/round
    # improvement claim and must stay >= 2x — same profile, same run,
    # same machine on both sides.
    ro_rounds, ro_depth = 24, 256
    ro_dense = _run_sharded(
        w, ro_rounds, gossip_mode="gated", delay_profile=f"het{ro_depth}", worker_kind="toy"
    )
    ro_sparse = _run_sharded(
        w, ro_rounds, gossip_mode="gated", capacity=cap,
        delay_profile=f"het{ro_depth}", worker_kind="toy",
    )
    out[f"roundstate_w{w}_d{ro_depth}_dense"] = ro_dense
    out[f"roundstate_w{w}_d{ro_depth}"] = ro_sparse
    ro_speedup = ro_dense["wall_ms_per_round"] / max(ro_sparse["wall_ms_per_round"], 1e-9)
    out[f"roundstate_w{w}_d{ro_depth}_speedup"] = ro_speedup
    pre = f"scaling.roundstate_w{w}_d{ro_depth}"
    lines.append(
        f"{pre}.dense_wall_ms_per_round,{ro_dense['wall_ms_per_round']:.1f},toy_worker"
    )
    lines.append(
        f"{pre}.sparse_wall_ms_per_round,{ro_sparse['wall_ms_per_round']:.1f},capacity_{cap}"
    )
    lines.append(f"{pre}.speedup_x,{ro_speedup:.2f},dense_over_sparse_wall")
    lines.append(
        f"{pre}.messages_evicted,{ro_sparse['messages_evicted']},{ro_sparse['rounds']}_rounds"
    )
    lines.append(
        f"{pre}.inflight_occupancy_peak,{ro_sparse['inflight_occupancy_peak']},capacity_{cap}"
    )
    lines.append(
        f"{pre}.certs_identical_to_dense,"
        f"{int(ro_sparse['certs_digest'] == ro_dense['certs_digest'])},het_delay_approx"
    )
    if ro_speedup < 2.0:
        raise RuntimeError(
            f"sparse in-flight state only {ro_speedup:.2f}x faster than the dense "
            f"buffer on the round-machinery benchmark (W={w}, depth={ro_depth}; "
            "expected >= 2x) — the bounded-queue wall-time claim no longer holds"
        )

    # (iii) heterogeneous delays in [1, 32] at W=1024: with mixed due
    # rounds a bounded queue IS an approximation (an evicted entry could
    # have won a later round's argmin), so the dense-vs-sparse gap is
    # MEASURED and reported — never asserted away. The occupancy peak
    # shows the capacity a bit-exact run would have needed.
    het_d = _run_sharded(w, rounds, delay_profile="het32")
    het_s = _run_sharded(w, rounds, capacity=cap, delay_profile="het32")
    out[f"sparse_w{w}_het32_dense"] = het_d
    out[f"sparse_w{w}_het32"] = het_s
    pre = f"scaling.sparse_w{w}_het32"
    lines.append(f"{pre}.wall_ms_per_round,{het_s['wall_ms_per_round']:.1f},capacity_{cap}")
    lines.append(
        f"{pre}.dense_wall_ms_per_round,{het_d['wall_ms_per_round']:.1f},same_run_dense"
    )
    lines.append(f"{pre}.messages_evicted,{het_s['messages_evicted']},{het_s['rounds']}_rounds")
    lines.append(
        f"{pre}.inflight_occupancy_peak,{het_s['inflight_occupancy_peak']},"
        f"exactness_needs_this_capacity"
    )
    gap = abs(het_s["best_cert"] - het_d["best_cert"])
    out[f"sparse_w{w}_het32_best_cert_gap"] = gap
    lines.append(f"{pre}.best_cert_gap_vs_dense,{gap:.5f},measured_divergence")
    lines.append(
        f"{pre}.certs_identical_to_dense,"
        f"{int(het_s['certs_digest'] == het_d['certs_digest'])},het_delay_approx"
    )

    # (iv) W=4096, delays in [1, 64], hard 9 GiB address-space cap: the
    # dense in-flight buffer is a single 4 GiB (4096, 4096, 64) f32
    # allocation plus its per-round shift copy (~8.6 GiB before any
    # worker state or runtime), so the dense attempt MUST die at
    # allocation while the sparse path (queues are W x C x 16 B, ~6.3
    # GiB peak address space all-in) completes the sweep under the
    # same cap.
    w4, mem_gb = 4096, 9
    dense4 = _run_sharded(
        w4, rounds, delay_profile="het64", mem_gb=mem_gb, check=False, timeout=1800
    )
    if dense4["completed"]:
        raise RuntimeError(
            f"dense in-flight buffer unexpectedly fit W={w4} under a {mem_gb} GiB "
            "address-space cap — the sparse memory-wall claim no longer holds"
        )
    sparse4 = _run_sharded(w4, rounds, capacity=cap, delay_profile="het64", mem_gb=mem_gb)
    out[f"dense_w{w4}_capped"] = dense4
    out[f"sparse_w{w4}"] = sparse4
    pre = f"scaling.sparse_w{w4}"
    lines.append(f"{pre}.completed,1,under_{mem_gb}gib_cap")
    lines.append(f"scaling.dense_w{w4}.completed,0,under_{mem_gb}gib_cap")
    lines.append(f"{pre}.wall_ms_per_round,{sparse4['wall_ms_per_round']:.1f},capacity_{cap}")
    lines.append(f"{pre}.per_segment_us,{sparse4['per_segment_us']:.0f},")
    lines.append(f"{pre}.messages_evicted,{sparse4['messages_evicted']},{sparse4['rounds']}_rounds")

    # --- control plane: dense certs/flags vs top-k candidate triples ------
    # W ∈ {4096, 10240} on the toy worker (round machinery is the cost),
    # gated gossip, sparse in-flight capacity 64, uniform delay, under
    # the same hard 9 GiB address-space cap as the memory-wall run — the
    # large-W regime the sparse control plane exists for. Dense control
    # gathers W_tier · 5 bytes of certs+flags every round; sparse
    # control ships only n_dev · k · 12 bytes of (cert, id, round)
    # triples. Under uniform delay the end state MUST be
    # digest-identical (suppressed runner-ups can never win a delivery
    # argmin — docs/architecture.md), and at W=10240 the control bytes
    # must collapse >= 10x — both failures are loud, not reported.
    for wc in (4096, 10240):
        pair = {}
        for plane in ("dense", "sparse"):
            res = _run_sharded(
                wc, rounds, gossip_mode="gated", capacity=cap, worker_kind="toy",
                mem_gb=9, control_plane=plane,
            )
            pair[plane] = res
            out[f"ctrl_w{wc}_{plane}"] = res
            pre = f"scaling.ctrl_w{wc}_{plane}"
            lines.append(
                f"{pre}.wall_ms_per_round,{res['wall_ms_per_round']:.1f},9gib_cap"
            )
            lines.append(
                f"{pre}.control_bytes_per_round,{res['control_bytes_per_round']},"
                f"{plane}_control"
            )
            lines.append(
                f"{pre}.gossip_bytes_per_round,{res['gossip_bytes_per_round']},incl_control"
            )
            lines.append(
                f"{pre}.ici_us_per_round,"
                f"{1e6 * ici_round_seconds(res['gossip_bytes_per_round']):.1f},"
                f"derived_wire_time"
            )
        if pair["sparse"]["certs_digest"] != pair["dense"]["certs_digest"]:
            # uniform delay: sparse control MUST reproduce dense control
            # exactly — a mismatch is an equivalence regression, not
            # noise, and has to fail the bench loudly
            raise RuntimeError(
                f"sparse control plane diverged from dense at W={wc} under uniform "
                f"delay: certs digest {pair['sparse']['certs_digest']} != "
                f"{pair['dense']['certs_digest']}"
            )
        lines.append(f"scaling.ctrl_w{wc}_sparse.certs_identical_to_dense,1,uniform_delay")
        ctrl_drop = pair["dense"]["control_bytes_per_round"] / max(
            pair["sparse"]["control_bytes_per_round"], 1
        )
        out[f"ctrl_w{wc}_reduction_sparse_vs_dense"] = ctrl_drop
        lines.append(
            f"scaling.ctrl_w{wc}_sparse.control_reduction_x,{ctrl_drop:.1f},"
            f"dense_over_sparse"
        )
        if wc == 10240 and ctrl_drop < 10.0:
            raise RuntimeError(
                f"sparse control plane only cut control bytes/round {ctrl_drop:.1f}x "
                f"at W={wc} (expected >= 10x) — the sparse-control traffic claim "
                "no longer holds"
            )

    # heterogeneous delays at W=4096: with mixed due rounds a suppressed
    # runner-up CAN win a later delivery argmin, so sparse control is an
    # approximation — the dense-vs-sparse certificate gap is MEASURED
    # and reported, never asserted away.
    wc = 4096
    chet_d = _run_sharded(
        wc, rounds, gossip_mode="gated", capacity=cap, worker_kind="toy",
        delay_profile="het32", control_plane="dense",
    )
    chet_s = _run_sharded(
        wc, rounds, gossip_mode="gated", capacity=cap, worker_kind="toy",
        delay_profile="het32", control_plane="sparse",
    )
    out[f"ctrl_w{wc}_het32_dense"] = chet_d
    out[f"ctrl_w{wc}_het32_sparse"] = chet_s
    pre = f"scaling.ctrl_w{wc}_het32"
    gap = abs(chet_s["best_cert"] - chet_d["best_cert"])
    out[f"ctrl_w{wc}_het32_best_cert_gap"] = gap
    lines.append(f"{pre}.best_cert_gap_vs_dense,{gap:.5f},measured_divergence")
    lines.append(
        f"{pre}.certs_identical_to_dense,"
        f"{int(chet_s['certs_digest'] == chet_d['certs_digest'])},het_delay_approx"
    )

    # roofline accounting of the fused delivery kernel at the sweep sizes
    from repro.launch.hlo_analysis import round_step_roofline

    for rw in (1024, w4):
        rf = round_step_roofline(rw, cap)
        out[f"round_step_roofline_w{rw}_c{cap}"] = rf
        pre = f"scaling.round_step_w{rw}_c{cap}"
        lines.append(
            f"{pre}.arith_intensity,{rf['arith_intensity_flops_per_byte']:.3f},"
            f"ridge_{rf['ridge_point_flops_per_byte']:.0f}_{rf['bound']}_bound"
        )
        lines.append(f"{pre}.projected_us,{rf['projected_us']:.2f},tpu_v5e_hbm_floor")
        lines.append(
            f"{pre}.fusion_overhead_x,{rf['fusion_overhead_x']:.2f},"
            f"ref_hlo_bytes_over_operand_floor"
        )

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "scaling.json"), "w") as f:
        json.dump(out, f, indent=1, default=float)
    return lines


def run_chaos(quick: bool = False) -> list[str]:
    """Chaos section: the MEASURED side of the fault/membership suite.

    The exact claims (join@k=1 identity, cross-substrate fault
    determinism, duplication transparency, corruption soundness) are
    pinned bit-for-bit in tests/test_chaos.py; what remains is measured
    here and reported, never assumed:

      * a churn trace at W=256 — 64 spares join while 64 founding
        workers leave (a quarter of the cluster churning in each
        direction) — must COMPLETE without deadlock, count exactly 64
        joins, and its best-certificate gap vs the clean run is the
        resilience figure;
      * the CI chaos leg's FaultPlan (drop=3,corrupt=3,seed=9) at
        W=256: injected-drop / rejected-corruption accounting plus the
        cert gap the low-rate faults actually cost;
      * a DCN pod partition on the (2, 4) pod mesh: cross-pod traffic
        severed for the middle third of the run — the two pods keep
        gossiping internally, re-merge when the window closes, and the
        cert gap vs the unpartitioned run measures what the partition
        cost.

    All runs use the trivial-segment worker (the chaos machinery, not
    worker compute, is under test), gated gossip, and the sparse
    pending-queue in-flight state — the large-W configuration the
    elastic layer exists for."""
    lines: list[str] = []
    out: dict = {}
    w, cap = 256, 64
    rounds = 24 if quick else 48
    kw = dict(gossip_mode="gated", capacity=cap, worker_kind="toy")

    clean = _run_sharded(w, rounds, **kw)
    out["clean"] = clean
    lines.append(f"chaos.clean_w{w}.wall_ms_per_round,{clean['wall_ms_per_round']:.1f},reference")
    lines.append(f"chaos.clean_w{w}.best_cert,{clean['best_cert']:.5f},reference")

    # --- churn trace: 64 joins + 64 leaves = a quarter churning each way
    churn = w // 4
    res = _run_sharded(w, rounds, churn=churn, **kw)
    out["churn"] = res
    if res["workers_joined"] != churn:
        # join accounting is exact — a miscount is a regression, not noise
        raise RuntimeError(
            f"churn trace joined {res['workers_joined']} workers, expected {churn}"
        )
    pre = f"chaos.churn_w{w}"
    gap = abs(res["best_cert"] - clean["best_cert"])
    out["churn_best_cert_gap"] = gap
    lines.append(f"{pre}.completed,1,{churn}_join_{churn}_leave_no_deadlock")
    lines.append(f"{pre}.workers_joined,{res['workers_joined']},exact_accounting")
    lines.append(f"{pre}.wall_ms_per_round,{res['wall_ms_per_round']:.1f},capacity_{cap}")
    lines.append(f"{pre}.best_cert_gap_vs_clean,{gap:.5f},measured_divergence")

    # --- the CI chaos leg's fault plan, measured at bench scale ----------
    spec = "drop=3,corrupt=3,seed=9"
    res = _run_sharded(w, rounds, fault_spec=spec, **kw)
    out["faults"] = res
    if res["messages_dropped_injected"] <= 0 or res["messages_corrupt_rejected"] <= 0:
        raise RuntimeError(
            f"fault plan {spec!r} injected nothing "
            f"(dropped={res['messages_dropped_injected']}, "
            f"rejected={res['messages_corrupt_rejected']})"
        )
    pre = f"chaos.faults_w{w}"
    gap = abs(res["best_cert"] - clean["best_cert"])
    out["faults_best_cert_gap"] = gap
    tag = spec.replace("=", "").replace(",", "_")  # CSV derived col: no commas
    lines.append(f"{pre}.messages_dropped_injected,{res['messages_dropped_injected']},{tag}")
    lines.append(f"{pre}.messages_corrupt_rejected,{res['messages_corrupt_rejected']},eps_gate_soundness")
    lines.append(f"{pre}.best_cert_gap_vs_clean,{gap:.5f},measured_divergence")

    # --- DCN pod partition: cross-pod tier severed mid-run ----------------
    pod_kw = dict(pods=2, cross_k=1, **kw)
    part_lo, part_hi = rounds // 3, 2 * rounds // 3
    pod_clean = _run_sharded(w, rounds, **pod_kw)
    pod_part = _run_sharded(
        w, rounds, fault_spec=f"part={part_lo}:{part_hi},seed=9", **pod_kw
    )
    out["pod_clean"] = pod_clean
    out["pod_partition"] = pod_part
    if pod_part["messages_dropped_injected"] <= 0:
        raise RuntimeError(
            f"pod partition window [{part_lo}, {part_hi}) dropped no cross-pod "
            "traffic — the partition fault is not reaching the pod tier"
        )
    pre = f"chaos.partition_pod2_w{w}"
    gap = abs(pod_part["best_cert"] - pod_clean["best_cert"])
    out["partition_best_cert_gap"] = gap
    lines.append(f"{pre}.completed,1,window_{part_lo}_{part_hi}_no_deadlock")
    lines.append(f"{pre}.messages_dropped_injected,{pod_part['messages_dropped_injected']},cross_pod_only")
    lines.append(f"{pre}.best_cert_gap_vs_clean,{gap:.5f},measured_divergence")
    lines.append(f"{pre}.wall_ms_per_round,{pod_part['wall_ms_per_round']:.1f},2x4_pod_mesh")

    os.makedirs(RESULTS, exist_ok=True)
    with open(os.path.join(RESULTS, "chaos.json"), "w") as f:
        json.dump(out, f, indent=1, default=float)
    return lines


def _main() -> None:
    if len(sys.argv) >= 2 and sys.argv[1] == "--sharded-child":
        w, n_dev, rounds = (int(a) for a in sys.argv[2:5])
        mode = sys.argv[5] if len(sys.argv) > 5 else "dense"
        pods = int(sys.argv[6]) if len(sys.argv) > 6 else 1
        cross_k = int(sys.argv[7]) if len(sys.argv) > 7 else 1
        capacity = int(sys.argv[8]) if len(sys.argv) > 8 else 0
        delay_profile = sys.argv[9] if len(sys.argv) > 9 else "uniform"
        mem_gb = int(sys.argv[10]) if len(sys.argv) > 10 else 0
        worker_kind = sys.argv[11] if len(sys.argv) > 11 else "sparrow"
        control_plane = sys.argv[12] if len(sys.argv) > 12 else "dense"
        fault_spec = sys.argv[13] if len(sys.argv) > 13 else ""
        churn = int(sys.argv[14]) if len(sys.argv) > 14 else 0
        print(
            json.dumps(
                _sharded_child(
                    w, n_dev, rounds, mode, pods, cross_k, capacity, delay_profile, mem_gb,
                    worker_kind, control_plane, fault_spec, churn,
                )
            ),
            flush=True,
        )
        return
    for line in run(quick=True):
        print(line)


if __name__ == "__main__":
    _main()
