"""Offline markdown link checker (stdlib only — runs in CI without an
install step, and inside the fast test tier via tests/test_docs.py).

  python tools/check_md_links.py README.md docs ROADMAP.md ...

Checks, for every ``[text](target)`` in the given files/directories:

  * relative file targets resolve to an existing file or directory
    (relative to the markdown file that contains the link);
  * ``#anchor`` fragments — bare or attached to a relative file —
    resolve to a heading in the target file (GitHub slug rules:
    lowercase, punctuation stripped, spaces to dashes);
  * absolute http(s) URLs are NOT fetched (CI must stay hermetic);
    they are only reported with --list-external.

Exit code 1 with a per-link report when anything is broken.
"""

from __future__ import annotations

import argparse
import re
import sys
from pathlib import Path

# [text](target) — skips images' leading ! only for the text capture;
# image paths are checked like any other relative target
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$")
CODE_FENCE_RE = re.compile(r"^(```|~~~)")


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: strip markdown emphasis/code ticks, drop
    punctuation, lowercase, spaces to dashes."""
    text = re.sub(r"[`*_]", "", heading)
    text = re.sub(r"\[([^\]]*)\]\([^)]*\)", r"\1", text)  # linked headings
    text = re.sub(r"[^\w\- ]", "", text, flags=re.UNICODE)
    return text.strip().lower().replace(" ", "-")


def heading_slugs(md_path: Path) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    in_fence = False
    for line in md_path.read_text(encoding="utf-8").splitlines():
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        m = HEADING_RE.match(line)
        if m:
            slug = github_slug(m.group(1))
            n = counts.get(slug, 0)
            counts[slug] = n + 1
            slugs.add(slug if n == 0 else f"{slug}-{n}")
    return slugs


def iter_links(md_path: Path):
    in_fence = False
    for lineno, line in enumerate(md_path.read_text(encoding="utf-8").splitlines(), 1):
        if CODE_FENCE_RE.match(line.strip()):
            in_fence = not in_fence
            continue
        if in_fence:
            continue
        for m in LINK_RE.finditer(line):
            yield lineno, m.group(1)


def check_file(md_path: Path, repo_root: Path) -> tuple[list[str], list[str], int]:
    """Returns (errors, external_urls, links_checked) for one file."""
    errors: list[str] = []
    external: list[str] = []
    n_links = 0
    for lineno, target in iter_links(md_path):
        n_links += 1
        where = f"{md_path.relative_to(repo_root)}:{lineno}"
        if target.startswith(("http://", "https://")):
            external.append(f"{where}: {target}")
            continue
        if target.startswith("mailto:"):
            continue
        path_part, _, fragment = target.partition("#")
        if path_part:
            dest = (md_path.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{where}: broken link -> {target} (no such file)")
                continue
        else:
            dest = md_path.resolve()
        if fragment:
            if dest.is_dir() or dest.suffix.lower() not in (".md", ".markdown"):
                errors.append(f"{where}: anchor on non-markdown target -> {target}")
            elif fragment.lower() not in heading_slugs(dest):
                errors.append(f"{where}: broken anchor -> {target}")
    return errors, external, n_links


def collect_md(paths: list[str], repo_root: Path) -> list[Path]:
    out: list[Path] = []
    for raw in paths:
        p = (repo_root / raw).resolve() if not Path(raw).is_absolute() else Path(raw)
        if p.is_dir():
            out.extend(sorted(p.rglob("*.md")))
        elif p.exists():
            out.append(p)
        else:
            print(f"warning: {raw} does not exist, skipping", file=sys.stderr)
    return out


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("paths", nargs="+", help="markdown files and/or directories")
    ap.add_argument("--root", default=None, help="repo root (default: this script's ../)")
    ap.add_argument("--list-external", action="store_true",
                    help="also print (unchecked) http(s) links")
    args = ap.parse_args()

    repo_root = Path(args.root).resolve() if args.root else Path(__file__).resolve().parent.parent
    files = collect_md(args.paths, repo_root)
    if not files:
        print("no markdown files found", file=sys.stderr)
        return 1

    all_errors: list[str] = []
    n_links = 0
    for md in files:
        errors, external, n = check_file(md, repo_root)
        n_links += n
        all_errors.extend(errors)
        if args.list_external:
            for line in external:
                print(f"  external (unchecked): {line}")

    print(f"checked {n_links} links across {len(files)} markdown files")
    if all_errors:
        print(f"\n{len(all_errors)} broken link(s):")
        for err in all_errors:
            print(f"  {err}")
        return 1
    print("all internal links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
