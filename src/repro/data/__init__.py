"""Data pipelines: synthetic splice-site-like generator for the
boosting experiments, and the token/embedding pipelines for the
transformer zoo."""

from repro.data.splice import make_splice_like, SpliceConfig
from repro.data.tokens import synthetic_token_batch, TokenPipeline

__all__ = ["make_splice_like", "SpliceConfig", "synthetic_token_batch", "TokenPipeline"]
