"""Synthetic stand-in for the human acceptor splice-site task (paper §5,
refs [3,4]: 50M training examples, heavily class-imbalanced, sequence
k-mer features).

The real dataset is not redistributable/offline here, so we generate a
structurally similar problem: categorical "position x nucleotide"
features (already bin-valued like one-hot k-mers), a sparse ground-truth
stump ensemble (a handful of motif positions carry the signal), strong
class imbalance, and label noise. What matters for reproducing the
paper's *systems* claims is the compute profile (examples x features
scanned per certified weak rule), which this preserves; the statistical
task is an analogue, not the original data — noted in EXPERIMENTS.md.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class SpliceConfig:
    n: int = 200_000
    d: int = 64  # feature count (motif positions)
    num_bins: int = 8  # categorical arity (k-mer alphabet)
    n_signal: int = 12  # features that actually carry signal
    pos_fraction: float = 0.3  # class balance (real task ~1%; kept moderate
    # so loss curves are informative at this scale)
    label_noise: float = 0.05
    seed: int = 0


def make_splice_like(cfg: SpliceConfig) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (xb (n,d) int32 bins, y (n,) float32 +-1, truth stumps)."""
    key = jax.random.PRNGKey(cfg.seed)
    k_x, k_sig, k_thr, k_sgn, k_noise, k_bias = jax.random.split(key, 6)
    xb = jax.random.randint(k_x, (cfg.n, cfg.d), 0, cfg.num_bins, dtype=jnp.int32)

    sig_feats = jax.random.choice(k_sig, cfg.d, shape=(cfg.n_signal,), replace=False)
    sig_thr = jax.random.randint(k_thr, (cfg.n_signal,), 0, cfg.num_bins - 1)
    sig_sgn = jnp.where(jax.random.bernoulli(k_sgn, 0.5, (cfg.n_signal,)), 1.0, -1.0)
    weights = jnp.linspace(2.0, 0.5, cfg.n_signal)  # few strong + tail of weak motifs

    votes = jnp.where(xb[:, sig_feats] > sig_thr[None, :], 1.0, -1.0) * sig_sgn[None, :]
    score = votes @ weights
    # bias to hit the target positive fraction
    bias = jnp.quantile(score, 1.0 - cfg.pos_fraction)
    y = jnp.where(score > bias, 1.0, -1.0)
    flip = jax.random.bernoulli(k_noise, cfg.label_noise, (cfg.n,))
    y = jnp.where(flip, -y, y).astype(jnp.float32)
    truth = jnp.stack([sig_feats.astype(jnp.float32), sig_thr.astype(jnp.float32), sig_sgn])
    return xb, y, truth


def train_test_split(
    xb: jnp.ndarray, y: jnp.ndarray, test_fraction: float = 0.1, seed: int = 1
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    n = xb.shape[0]
    perm = jax.random.permutation(jax.random.PRNGKey(seed), n)
    n_test = int(n * test_fraction)
    te, tr = perm[:n_test], perm[n_test:]
    return xb[tr], y[tr], xb[te], y[te]
