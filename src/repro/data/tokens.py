"""Synthetic token / embedding pipeline for the transformer zoo.

Provides deterministic synthetic batches for smoke tests and the
training examples, plus ``ShapeDtypeStruct`` specs for the dry-run (the
dry-run never allocates real data). Modality frontends (audio conv
codec, ViT patch encoder) are stubs per the assignment: ``TokenPipeline``
emits precomputed frame/patch embeddings of the right shape for those
architectures.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp


def synthetic_token_batch(
    key: jax.Array, batch: int, seq: int, vocab: int
) -> dict[str, jnp.ndarray]:
    """One LM batch: tokens + next-token labels (shifted) + mask."""
    tokens = jax.random.randint(key, (batch, seq), 0, vocab, dtype=jnp.int32)
    labels = jnp.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
    mask = jnp.ones((batch, seq), jnp.float32)
    return {"tokens": tokens, "labels": labels, "mask": mask}


@dataclasses.dataclass
class TokenPipeline:
    """Host-side infinite batch iterator with a fixed RNG lineage.

    Real deployments swap this for a file-backed loader; the interface
    (``__iter__`` of dict batches, ``element_spec``) is what the trainer
    depends on.
    """

    batch: int
    seq: int
    vocab: int
    seed: int = 0
    # modality stub: if set, also emit (batch, frontend_len, frontend_dim)
    # float embeddings (audio frames / vision patches)
    frontend_len: int = 0
    frontend_dim: int = 0

    def element_spec(self) -> dict[str, jax.ShapeDtypeStruct]:
        spec = {
            "tokens": jax.ShapeDtypeStruct((self.batch, self.seq), jnp.int32),
            "labels": jax.ShapeDtypeStruct((self.batch, self.seq), jnp.int32),
            "mask": jax.ShapeDtypeStruct((self.batch, self.seq), jnp.float32),
        }
        if self.frontend_len:
            spec["frontend_embeds"] = jax.ShapeDtypeStruct(
                (self.batch, self.frontend_len, self.frontend_dim), jnp.float32
            )
        return spec

    def __iter__(self) -> Iterator[dict[str, jnp.ndarray]]:
        key = jax.random.PRNGKey(self.seed)
        while True:
            key, sub = jax.random.split(key)
            b = synthetic_token_batch(sub, self.batch, self.seq, self.vocab)
            if self.frontend_len:
                key, sub2 = jax.random.split(key)
                b["frontend_embeds"] = (
                    jax.random.normal(sub2, (self.batch, self.frontend_len, self.frontend_dim))
                    * 0.02
                ).astype(jnp.float32)
            yield b
