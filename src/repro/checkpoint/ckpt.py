"""Minimal npz checkpointing: flattens any pytree (dicts / lists /
tuples / NamedTuples) with stable path keys. Suitable for the example
drivers and tests; a production deployment would swap in a
multi-host-aware store behind the same two calls."""

from __future__ import annotations

import os
from typing import Any

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def save_checkpoint(path: str, tree: Any) -> None:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    arrays = {_path_str(kp): np.asarray(v) for kp, v in flat}
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **arrays)


def load_checkpoint(path: str, like: Any) -> Any:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(path if path.endswith(".npz") else path + ".npz")
    flat, tree = jax.tree_util.tree_flatten_with_path(like)
    out = []
    for kp, v in flat:
        key = _path_str(kp)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if tuple(arr.shape) != tuple(v.shape):
            raise ValueError(f"shape mismatch at {key}: {arr.shape} vs {v.shape}")
        out.append(jax.numpy.asarray(arr, dtype=v.dtype))
    return jax.tree_util.tree_unflatten(tree, out)
