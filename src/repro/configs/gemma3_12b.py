"""Gemma3-12B — dense GQA with 5:1 local(sliding-window):global
attention, 128k context [hf:google/gemma-3-1b-pt family]."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b",
    arch_type="dense",
    num_layers=48,
    d_model=3840,
    num_heads=16,
    num_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    sliding_window=1024,
    local_ratio=5,           # 5 local : 1 global
    rope_theta=1_000_000.0,
    supports_long_decode=True,   # local layers are windowed; global
                                 # layers decode one token vs cache (linear)
    citation="hf:google/gemma-3-1b-pt",
)
