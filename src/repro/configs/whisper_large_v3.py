"""Whisper-large-v3 — enc-dec audio; conv/mel frontend is a STUB per the
assignment (frontend embeddings of the right shape feed the encoder)
[arXiv:2212.04356]. 32 encoder + 32 decoder layers, MHA (kv=20)."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    arch_type="audio",
    num_layers=32,           # decoder layers
    encoder_layers=32,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    mlp_gated=False,
    frontend="audio",
    frontend_len=1500,       # 30s of audio -> 1500 frames post-conv
    frontend_dim=128,        # stub mel/conv feature dim
    citation="arXiv:2212.04356",
)
