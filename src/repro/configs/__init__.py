"""Config registry: ``get_config(arch_id)`` / ``reduced(cfg)``.

One module per assigned architecture (exact specs from the assignment,
source cited in each file) plus the paper's own Sparrow config.
"""

from __future__ import annotations

import dataclasses
import importlib

from repro.models.config import ArchConfig

ARCH_IDS = [
    "yi_9b",
    "starcoder2_7b",
    "whisper_large_v3",
    "internlm2_20b",
    "zamba2_1p2b",
    "deepseek_v3_671b",
    "gemma3_12b",
    "mamba2_1p3b",
    "phi3_vision_4p2b",
    "grok1_314b",
]

_ALIASES = {
    "yi-9b": "yi_9b",
    "starcoder2-7b": "starcoder2_7b",
    "whisper-large-v3": "whisper_large_v3",
    "internlm2-20b": "internlm2_20b",
    "zamba2-1.2b": "zamba2_1p2b",
    "deepseek-v3-671b": "deepseek_v3_671b",
    "gemma3-12b": "gemma3_12b",
    "mamba2-1.3b": "mamba2_1p3b",
    "phi-3-vision-4.2b": "phi3_vision_4p2b",
    "grok-1-314b": "grok1_314b",
}


def get_config(arch_id: str) -> ArchConfig:
    mod_name = _ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "p"))
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def reduced(cfg: ArchConfig) -> ArchConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512,
    <=4 experts, tiny vocab — runs a CPU forward/train step."""
    kw: dict = dict(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads else 0,
        d_ff=512,
        vocab=512,
        head_dim=64 if cfg.head_dim else None,
        frontend_len=min(cfg.frontend_len, 16),
        frontend_dim=min(cfg.frontend_dim, 32) if cfg.frontend_dim else 0,
        param_dtype="float32",
        compute_dtype="float32",
        remat=False,
    )
    if cfg.num_experts:
        kw.update(
            num_experts=4,
            num_experts_per_tok=min(cfg.num_experts_per_tok, 2),
            moe_d_ff=128,
            first_k_dense=min(cfg.first_k_dense, 1),
            num_shared_experts=min(cfg.num_shared_experts, 1),
        )
    if cfg.attention == "mla":
        kw.update(
            q_lora_rank=64, kv_lora_rank=64, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32,
        )
    if cfg.ssm_state:
        kw.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=32)
    if cfg.arch_type == "hybrid":
        kw.update(shared_attn_every=1, num_layers=2)
    if cfg.local_ratio:
        kw.update(local_ratio=1, sliding_window=32, num_layers=2)
    if cfg.is_encdec():
        kw.update(encoder_layers=2)
    return dataclasses.replace(cfg, **kw)
