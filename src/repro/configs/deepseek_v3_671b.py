"""DeepSeek-V3 671B — MLA + fine-grained MoE (1 shared + 256 routed,
top-8) + MTP [arXiv:2412.19437]. First 3 layers dense."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    arch_type="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,        # MLA: latent cache, kv head count nominal
    d_ff=18432,              # dense-layer FFN
    moe_d_ff=2048,           # per routed expert
    vocab=129280,
    attention="mla",
    q_lora_rank=1536,
    kv_lora_rank=512,
    qk_nope_head_dim=128,
    qk_rope_head_dim=64,
    v_head_dim=128,
    num_experts=256,
    num_experts_per_tok=8,
    num_shared_experts=1,
    first_k_dense=3,
    mtp_depth=1,
    citation="arXiv:2412.19437",
)
