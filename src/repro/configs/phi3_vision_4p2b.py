"""Phi-3-vision-4.2B — phi3-mini LM backbone + CLIP vision frontend
(STUB per assignment: patch embeddings spliced into the first
frontend_len positions) [hf:microsoft/Phi-3-vision-128k-instruct]."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="phi-3-vision-4.2b",
    arch_type="vlm",
    num_layers=32,
    d_model=3072,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=32064,
    frontend="vision",
    frontend_len=576,        # 24x24 CLIP patches
    frontend_dim=1024,       # CLIP-L feature dim
    citation="hf:microsoft/Phi-3-vision-128k-instruct",
)
