"""Grok-1 314B — MoE, 8 experts top-2 [hf:xai-org/grok-1]."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=32768,              # dense path unused (all layers MoE)
    moe_d_ff=32768,
    vocab=131072,
    num_experts=8,
    num_experts_per_tok=2,
    citation="hf:xai-org/grok-1",
)
