"""InternLM2-20B — dense GQA [arXiv:2403.17297]."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-20b",
    arch_type="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab=92544,
    rope_theta=1_000_000.0,
    citation="arXiv:2403.17297",
)
