"""The paper's own system config: Sparrow on the splice-site analogue."""

from repro.boosting.scanner import ScannerConfig
from repro.boosting.sparrow import SparrowConfig
from repro.data.splice import SpliceConfig

DATA = SpliceConfig(n=200_000, d=64, num_bins=8, seed=0)

def sparrow_config(n_workers: int = 10, sample_frac: float = 0.1) -> SparrowConfig:
    return SparrowConfig(
        sample_size=int(DATA.n * sample_frac * 0.9),  # 10% of train split
        capacity=256,
        scanner=ScannerConfig(chunk_size=2048, num_bins=DATA.num_bins, gamma0=0.25),
        ess_threshold=0.1,
        n_workers=n_workers,
    )
