"""Zamba2-1.2B — hybrid: Mamba2 backbone + ONE weight-shared attention
block applied every 6 SSM layers [arXiv:2411.15242]. 38 Mamba2 layers,
shared block is MHA (32 heads, kv=32), d_ff=8192."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-1.2b",
    arch_type="hybrid",
    num_layers=38,           # ssm layers; shared attn applications extra
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab=32000,
    ssm_state=64,
    ssm_head_dim=64,
    shared_attn_every=6,
    supports_long_decode=True,   # SSM state is O(1); attn KV grows but
                                 # only in the handful of shared blocks
    citation="arXiv:2411.15242",
)
