"""StarCoder2-7B — dense GQA, RoPE [arXiv:2402.19173]."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-7b",
    arch_type="dense",
    num_layers=32,
    d_model=4608,
    num_heads=36,
    num_kv_heads=4,
    d_ff=18432,
    vocab=49152,
    mlp_gated=False,
    rope_theta=1_000_000.0,
    citation="arXiv:2402.19173",
)
