"""Mamba2-1.3B — attention-free SSD (state-space duality)
[arXiv:2405.21060]. 48 layers, d_model=2048, state=128."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    arch_type="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab=50280,
    ssm_state=128,
    ssm_head_dim=64,
    supports_long_decode=True,
    citation="arXiv:2405.21060",
)
