"""Yi-9B — llama-arch dense GQA [arXiv:2403.04652]."""

from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="yi-9b",
    arch_type="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab=64000,
    rope_theta=5_000_000.0,
    citation="arXiv:2403.04652",
)
