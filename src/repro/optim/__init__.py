from repro.optim.adamw import AdamWConfig, init_opt_state, apply_updates
from repro.optim.schedule import warmup_cosine

__all__ = ["AdamWConfig", "init_opt_state", "apply_updates", "warmup_cosine"]
