"""Functional AdamW with configurable state dtype.

``state_dtype="bfloat16"`` halves optimizer memory for the giant
configs (deepseek-671b, grok-314b) — the dry-run reports both choices'
bytes-per-device; DESIGN.md §5 discusses the trade-off.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    state_dtype: str = "float32"  # or "bfloat16"


def _sdt(cfg: AdamWConfig):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]


def init_opt_state(params: Any, cfg: AdamWConfig) -> dict:
    dt = _sdt(cfg)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def apply_updates(
    params: Any, grads: Any, state: dict, cfg: AdamWConfig, lr: jnp.ndarray | float | None = None
) -> tuple[Any, dict]:
    dt = _sdt(cfg)
    step = state["step"] + 1
    lr = cfg.lr if lr is None else lr
    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g32
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mhat = mu32 / b1c
        nhat = nu32 / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu32.astype(dt), nu32.astype(dt)

    flat_p, tree = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_mu = jax.tree.leaves(state["mu"])
    flat_nu = jax.tree.leaves(state["nu"])
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    new_p = tree.unflatten([o[0] for o in out])
    new_mu = tree.unflatten([o[1] for o in out])
    new_nu = tree.unflatten([o[2] for o in out])
    return new_p, {"mu": new_mu, "nu": new_nu, "step": step}
