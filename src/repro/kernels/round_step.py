"""Pallas TPU kernel for the engine's fused sparse round step.

The sparse engine (``EngineConfig.inflight_capacity > 0``) keeps a
bounded per-destination :class:`~repro.core.engine.PendingQueue` of
(cert, src, due, ring-slot) entries instead of the dense ``(W, W, D)``
in-flight buffer. Its per-round delivery hot path is four elementwise/
reduction passes over the ``(W, C)`` queue plus the per-worker credit
update — all VPU work with no cross-row dependence, so this kernel
fuses them into ONE pass per row tile:

  1. delivery argmin: among entries due this round, the minimum by
     (cert, src) — the same lexicographic tie-break as the dense
     engine's ``argmin`` (lowest source id wins ties);
  2. eps-gated accept: ``best_cert < certs0 - eps`` (the protocol's
     ``accepts``), masked to alive destinations;
  3. arrival clearing: delivered entries drop their cert to +inf
     (dues are absolute, so a stale due can never re-match — this
     replaces the dense buffer's O(W²·D) shift);
  4. laggard-credit update: ``credit += speed_norm``; workers whose
     credit covers a segment spend it (``active``).

Grid: one step per ``tile_w`` destination rows; every block is
resident for exactly one step (no cross-step accumulation). Boolean
masks cross the kernel boundary as int32 (TPU-friendly); the wrapper
converts. ``kernels/ref.py::round_step_ref`` is the bit-identical
pure-jnp oracle (and the engine's ``round_step_impl="ref"`` path).

:func:`queue_ingest` is the sparse-CONTROL-plane companion
(``EngineConfig.control_plane="sparse"``): instead of scanning a dense
(W,) broadcast-score vector, it merges an explicit (W, m) candidate
block — the scattered payload of the (n_dev, k) control all_gather —
into the pending queues with the same worst-certificate-first
eviction order, via a loop-free rank-select (see the kernel body) that
bit-matches the jnp oracle's stable lexsort.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_I32_MAX = 2**31 - 1


def _round_step_kernel(
    q_cert_ref,
    q_due_ref,
    q_src_ref,
    q_slot_ref,
    certs0_ref,
    alive_ref,
    credit_ref,
    speed_ref,
    r_ref,
    q_cert_out_ref,
    best_cert_ref,
    best_src_ref,
    best_slot_ref,
    take_ref,
    n_arr_ref,
    credit_out_ref,
    active_ref,
    *,
    eps: float,
):
    qc = q_cert_ref[...]  # (tw, C) f32
    qd = q_due_ref[...]  # (tw, C) i32
    qs = q_src_ref[...]  # (tw, C) i32
    ql = q_slot_ref[...]  # (tw, C) i32
    certs0 = certs0_ref[...]  # (tw, 1) f32
    alive = alive_ref[...] != 0  # (tw, 1) bool
    credit = credit_ref[...]  # (tw, 1) f32
    speed = speed_ref[...]  # (tw, 1) f32
    r = r_ref[0, 0]  # () i32

    arr = (qd == r) & jnp.isfinite(qc)  # entries delivered this round
    arr_live = jnp.where(arr & alive, qc, jnp.inf)
    best_cert = jnp.min(arr_live, axis=1, keepdims=True)  # (tw, 1)
    finite = jnp.isfinite(best_cert)
    hit = (arr_live == best_cert) & finite
    best_src = jnp.min(jnp.where(hit, qs, _I32_MAX), axis=1, keepdims=True)
    sel = hit & (qs == best_src)
    best_slot = jnp.min(jnp.where(sel, ql, _I32_MAX), axis=1, keepdims=True)

    best_cert_ref[...] = best_cert
    best_src_ref[...] = jnp.where(finite, best_src, 0)
    best_slot_ref[...] = jnp.where(finite, best_slot, 0)
    take_ref[...] = (finite & (best_cert < certs0 - eps)).astype(jnp.int32)
    n_arr_ref[...] = jnp.sum(arr.astype(jnp.int32), axis=1, keepdims=True)
    # delivered entries (dead destinations included — they drain and
    # count as arrivals exactly like the dense buffer's shift-out)
    q_cert_out_ref[...] = jnp.where(arr, jnp.inf, qc)

    credit2 = credit + speed
    active = alive & (credit2 >= 1.0 - 1e-6)
    credit_out_ref[...] = jnp.where(active, credit2 - 1.0, credit2)
    active_ref[...] = active.astype(jnp.int32)


def _queue_ingest_kernel(
    q_cert_ref,
    q_due_ref,
    q_src_ref,
    q_slot_ref,
    c_cert_ref,
    c_due_ref,
    c_src_ref,
    c_slot_ref,
    o_cert_ref,
    o_due_ref,
    o_src_ref,
    o_slot_ref,
):
    cert = jnp.concatenate([q_cert_ref[...], c_cert_ref[...]], axis=1)  # (tw, n)
    due = jnp.concatenate([q_due_ref[...], c_due_ref[...]], axis=1)
    src = jnp.concatenate([q_src_ref[...], c_src_ref[...]], axis=1)
    slot = jnp.concatenate([q_slot_ref[...], c_slot_ref[...]], axis=1)
    n = cert.shape[1]
    cap = q_cert_ref.shape[1]

    # rank-select instead of an in-kernel sort: with the column position
    # as the final tie-break the lex key (cert, src, due, position) is a
    # TOTAL order, so "rank = number of strict predecessors" is a
    # permutation of 0..n-1 that bit-matches the stable
    # lexsort((due, src, cert)) of the jnp oracle. One (n, n) pairwise
    # comparison per row, all VPU-friendly elementwise + reduction work.
    a_cert, b_cert = cert[:, :, None], cert[:, None, :]
    a_src, b_src = src[:, :, None], src[:, None, :]
    a_due, b_due = due[:, :, None], due[:, None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (n, n), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (n, n), 1)
    cert_eq = a_cert == b_cert
    src_eq = a_src == b_src
    lt = (
        (a_cert < b_cert)
        | (cert_eq & (a_src < b_src))
        | (cert_eq & src_eq & (a_due < b_due))
        | (cert_eq & src_eq & (a_due == b_due) & (ii < jj)[None])
    )
    rank = jnp.sum(lt.astype(jnp.int32), axis=1)  # (tw, n) predecessors of col j

    # scatter-by-rank: output column c takes the unique element of rank
    # c (one-hot select + sum — exact for ints and for +inf certs)
    sel = rank[:, None, :] == jax.lax.broadcasted_iota(jnp.int32, (1, cap, n), 1)
    o_cert_ref[...] = jnp.sum(jnp.where(sel, cert[:, None, :], 0.0), axis=2)
    o_due_ref[...] = jnp.sum(jnp.where(sel, due[:, None, :], 0), axis=2)
    o_src_ref[...] = jnp.sum(jnp.where(sel, src[:, None, :], 0), axis=2)
    o_slot_ref[...] = jnp.sum(jnp.where(sel, slot[:, None, :], 0), axis=2)


@functools.partial(jax.jit, static_argnames=("tile_w", "interpret"))
def queue_ingest(
    q_cert: jnp.ndarray,
    q_due: jnp.ndarray,
    q_src: jnp.ndarray,
    q_slot: jnp.ndarray,
    c_cert: jnp.ndarray,
    c_due: jnp.ndarray,
    c_src: jnp.ndarray,
    c_slot: jnp.ndarray,
    *,
    tile_w: int = 128,
    interpret: bool = True,
):
    """Sparse-control candidate-list ingest: merge the (W, m) candidate
    block into the (W, C) pending queues, keeping the lexicographically
    smallest C per row by (cert, src, due) — worst-certificate-first
    eviction. Bit-identical to ``kernels/ref.py::queue_ingest_ref``
    (pinned in tests/test_kernels.py).

    Args:
        q_cert/q_due/q_src/q_slot: (W, C) PendingQueue leaves.
        c_cert/c_due/c_src/c_slot: (W, m) candidate block — +inf cert /
            due -1 marks an invalid (padded or self/OOB) candidate.
        tile_w: destination rows per grid step.
        interpret: interpret mode (CPU container); False on a real TPU.

    Returns ``(q_cert', q_due', q_src', q_slot')``, each (W, C).
    """
    w, cap = q_cert.shape
    m = c_cert.shape[1]
    w_pad = -w % tile_w
    if w_pad:
        q_cert = jnp.pad(q_cert, ((0, w_pad), (0, 0)), constant_values=jnp.inf)
        q_due = jnp.pad(q_due, ((0, w_pad), (0, 0)), constant_values=-1)
        q_src = jnp.pad(q_src, ((0, w_pad), (0, 0)))
        q_slot = jnp.pad(q_slot, ((0, w_pad), (0, 0)))
        c_cert = jnp.pad(c_cert, ((0, w_pad), (0, 0)), constant_values=jnp.inf)
        c_due = jnp.pad(c_due, ((0, w_pad), (0, 0)), constant_values=-1)
        c_src = jnp.pad(c_src, ((0, w_pad), (0, 0)))
        c_slot = jnp.pad(c_slot, ((0, w_pad), (0, 0)))
    steps = q_cert.shape[0] // tile_w

    row = lambda i: (i, 0)  # noqa: E731
    queue_spec = pl.BlockSpec((tile_w, cap), row)
    cand_spec = pl.BlockSpec((tile_w, m), row)
    out = pl.pallas_call(
        _queue_ingest_kernel,
        grid=(steps,),
        in_specs=[queue_spec] * 4 + [cand_spec] * 4,
        out_specs=[queue_spec] * 4,
        out_shape=[
            jax.ShapeDtypeStruct((w + w_pad, cap), jnp.float32),
            jax.ShapeDtypeStruct((w + w_pad, cap), jnp.int32),
            jax.ShapeDtypeStruct((w + w_pad, cap), jnp.int32),
            jax.ShapeDtypeStruct((w + w_pad, cap), jnp.int32),
        ],
        interpret=interpret,
    )(q_cert, q_due, q_src, q_slot, c_cert, c_due, c_src, c_slot)
    return tuple(a[:w] for a in out)


@functools.partial(jax.jit, static_argnames=("eps", "tile_w", "interpret"))
def round_step(
    q_cert: jnp.ndarray,
    q_due: jnp.ndarray,
    q_src: jnp.ndarray,
    q_slot: jnp.ndarray,
    certs0: jnp.ndarray,
    alive: jnp.ndarray,
    credit: jnp.ndarray,
    speed_norm: jnp.ndarray,
    r: jnp.ndarray,
    *,
    eps: float,
    tile_w: int = 128,
    interpret: bool = True,
):
    """Fused sparse delivery + accept + credit; see the module docstring.

    Args:
        q_cert/q_due/q_src/q_slot: (W, C) PendingQueue leaves.
        certs0: (W,) f32 current certificates.
        alive: (W,) int32 (nonzero = alive destination).
        credit: (W,) f32 compute credit before this round.
        speed_norm: (W,) f32 normalized per-worker speed.
        r: () i32 current round.
        eps: static protocol acceptance gap.
        tile_w: destination rows per grid step.
        interpret: interpret mode (CPU container); False on a real TPU.

    Returns ``(q_cert', best_cert, best_src, best_slot, take, n_arr,
    credit', active)`` — (W, C) and seven (W,) arrays; ``take`` and
    ``active`` are int32 masks.
    """
    w, cap = q_cert.shape
    w_pad = -w % tile_w
    if w_pad:
        q_cert = jnp.pad(q_cert, ((0, w_pad), (0, 0)), constant_values=jnp.inf)
        q_due = jnp.pad(q_due, ((0, w_pad), (0, 0)), constant_values=-1)
        q_src = jnp.pad(q_src, ((0, w_pad), (0, 0)))
        q_slot = jnp.pad(q_slot, ((0, w_pad), (0, 0)))
        certs0 = jnp.pad(certs0, (0, w_pad))
        alive = jnp.pad(alive, (0, w_pad))
        credit = jnp.pad(credit, (0, w_pad))
        speed_norm = jnp.pad(speed_norm, (0, w_pad))
    steps = q_cert.shape[0] // tile_w

    row = lambda i: (i, 0)  # noqa: E731
    rep = lambda i: (0, 0)  # noqa: E731
    vec_spec = pl.BlockSpec((tile_w, 1), row)
    out = pl.pallas_call(
        functools.partial(_round_step_kernel, eps=eps),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((tile_w, cap), row),
            pl.BlockSpec((tile_w, cap), row),
            pl.BlockSpec((tile_w, cap), row),
            pl.BlockSpec((tile_w, cap), row),
            vec_spec,
            vec_spec,
            vec_spec,
            vec_spec,
            pl.BlockSpec((1, 1), rep),
        ],
        out_specs=[
            pl.BlockSpec((tile_w, cap), row),
            vec_spec,
            vec_spec,
            vec_spec,
            vec_spec,
            vec_spec,
            vec_spec,
            vec_spec,
        ],
        out_shape=[
            jax.ShapeDtypeStruct((w + w_pad, cap), jnp.float32),
            jax.ShapeDtypeStruct((w + w_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((w + w_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((w + w_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((w + w_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((w + w_pad, 1), jnp.int32),
            jax.ShapeDtypeStruct((w + w_pad, 1), jnp.float32),
            jax.ShapeDtypeStruct((w + w_pad, 1), jnp.int32),
        ],
        interpret=interpret,
    )(
        q_cert,
        q_due,
        q_src,
        q_slot,
        certs0.reshape(-1, 1),
        alive.reshape(-1, 1).astype(jnp.int32),
        credit.reshape(-1, 1),
        speed_norm.reshape(-1, 1),
        r.reshape(1, 1).astype(jnp.int32),
    )
    q_cert_new, best_cert, best_src, best_slot, take, n_arr, credit_new, active = out
    trim = lambda a: a[:w, 0]  # noqa: E731
    return (
        q_cert_new[:w],
        trim(best_cert),
        trim(best_src),
        trim(best_slot),
        trim(take),
        trim(n_arr),
        trim(credit_new),
        trim(active),
    )
