"""Public jit'd entry points for the Pallas kernels.

On this CPU container the kernels execute in interpret mode; on a real
TPU deployment ``interpret`` resolves to False and the same call sites
get the compiled Mosaic kernels. Tile sizes default to MXU-aligned
values (the second-minor dim of every matmul operand is a multiple of
128 when d*B is — configs pick d and B accordingly; see DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_scan import edge_scan as _edge_scan
from repro.kernels.weight_update import scatter_model_slice, weight_update as _weight_update


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def edge_scan(
    xb: jnp.ndarray,
    wy: jnp.ndarray,
    w: jnp.ndarray,
    *,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool | None = None,
):
    """(hist (d,B), W, V, T) — see :mod:`repro.kernels.edge_scan`."""
    if interpret is None:
        interpret = _default_interpret()
    return _edge_scan(xb, wy, w, num_bins=num_bins, tile_n=tile_n, interpret=interpret)


def weight_update(
    xb: jnp.ndarray,
    y: jnp.ndarray,
    margin_l: jnp.ndarray,
    margin_s: jnp.ndarray,
    a: jnp.ndarray,
    c: jnp.ndarray,
    *,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool | None = None,
):
    """(margin_new, w) — see :mod:`repro.kernels.weight_update`."""
    if interpret is None:
        interpret = _default_interpret()
    return _weight_update(
        xb, y, margin_l, margin_s, a, c, num_bins=num_bins, tile_n=tile_n, interpret=interpret
    )


__all__ = ["edge_scan", "weight_update", "scatter_model_slice"]
