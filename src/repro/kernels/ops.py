"""Public jit'd entry points for the Pallas kernels.

On this CPU container the kernels execute in interpret mode; on a real
TPU deployment ``interpret`` resolves to False and the same call sites
get the compiled Mosaic kernels. Tile sizes default to MXU-aligned
values (the second-minor dim of every matmul operand is a multiple of
128 when d*B is — configs pick d and B accordingly; see DESIGN.md §6).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.edge_scan import edge_scan as _edge_scan
from repro.kernels.round_step import queue_ingest as _queue_ingest, round_step as _round_step
from repro.kernels.weight_update import scatter_model_slice, weight_update as _weight_update


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def edge_scan(
    xb: jnp.ndarray,
    wy: jnp.ndarray,
    w: jnp.ndarray,
    *,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool | None = None,
):
    """(hist (d,B), W, V, T) — see :mod:`repro.kernels.edge_scan`."""
    if interpret is None:
        interpret = _default_interpret()
    return _edge_scan(xb, wy, w, num_bins=num_bins, tile_n=tile_n, interpret=interpret)


def edge_scan_batched(
    xb: jnp.ndarray,
    wy: jnp.ndarray,
    w: jnp.ndarray,
    *,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool | None = None,
):
    """Batched edge scan over a leading worker axis.

    Args are the stacked counterparts of :func:`edge_scan`: ``xb``
    (W, n, d), ``wy``/``w`` (W, n). ``vmap`` of a ``pallas_call``
    prepends a batch dimension to the kernel grid, so all W histogram
    accumulations run in one launch. This standalone entry point is the
    kernel-level counterpart of what the batched Sparrow scanner does
    implicitly (it vmaps ``scan_chunk``, which calls :func:`edge_scan`
    inside the vmapped region — the same batch-grid lowering);
    ``tests/test_kernels.py`` pins the two-path equivalence against W
    independent launches.

    Returns (hist (W, d, B), W_ (W,), V (W,), T (W,)).
    """
    if interpret is None:
        interpret = _default_interpret()
    fn = functools.partial(_edge_scan, num_bins=num_bins, tile_n=tile_n, interpret=interpret)
    return jax.vmap(fn)(xb, wy, w)


def edge_scan_sharded(
    xb: jnp.ndarray,
    wy: jnp.ndarray,
    w: jnp.ndarray,
    *,
    mesh,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool | None = None,
):
    """:func:`edge_scan_batched` sharded over a ``workers`` mesh axis.

    The kernel-level counterpart of the sharded engine's scan path:
    ``shard_map`` partitions the leading worker axis over the mesh, and
    each device runs the vmapped ``pallas_call`` on only its local
    worker shard — per-worker histograms need no collective at all (the
    (d, B) accumulation is private to a worker), so the whole scan is
    embarrassingly parallel and the launch grid per device shrinks from
    W to W_local. ``tests/test_kernels.py`` pins the output against the
    unsharded batched path when multiple devices are visible.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    if interpret is None:
        interpret = _default_interpret()
    if xb.shape[0] % mesh.shape["workers"]:
        raise ValueError(
            f"worker axis {xb.shape[0]} must divide over {mesh.shape['workers']} devices"
        )
    fn = functools.partial(_edge_scan, num_bins=num_bins, tile_n=tile_n, interpret=interpret)
    sharded = shard_map(
        lambda a, b, c: jax.vmap(fn)(a, b, c),
        mesh=mesh,
        in_specs=(P("workers"), P("workers"), P("workers")),
        out_specs=(P("workers"), P("workers"), P("workers"), P("workers")),
        check_rep=False,
    )
    return sharded(xb, wy, w)


def weight_update(
    xb: jnp.ndarray,
    y: jnp.ndarray,
    margin_l: jnp.ndarray,
    margin_s: jnp.ndarray,
    a: jnp.ndarray,
    c: jnp.ndarray,
    *,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool | None = None,
):
    """(margin_new, w) — see :mod:`repro.kernels.weight_update`."""
    if interpret is None:
        interpret = _default_interpret()
    return _weight_update(
        xb, y, margin_l, margin_s, a, c, num_bins=num_bins, tile_n=tile_n, interpret=interpret
    )


def round_deliver(
    q_cert: jnp.ndarray,
    q_due: jnp.ndarray,
    q_src: jnp.ndarray,
    q_slot: jnp.ndarray,
    certs0: jnp.ndarray,
    alive: jnp.ndarray,
    credit: jnp.ndarray,
    speed_norm: jnp.ndarray,
    r: jnp.ndarray,
    *,
    eps: float,
    tile_w: int = 128,
    interpret: bool | None = None,
):
    """Fused sparse delivery + eps-gated accept + laggard credit.

    Same contract as :func:`repro.kernels.ref.round_step_ref` (bool
    ``alive`` in, bool ``take``/``active`` out); the int32 conversion
    the TPU kernel needs at its boundary happens here.
    """
    if interpret is None:
        interpret = _default_interpret()
    out = _round_step(
        q_cert,
        q_due,
        q_src,
        q_slot,
        certs0,
        alive.astype(jnp.int32),
        credit,
        speed_norm,
        r,
        eps=eps,
        tile_w=tile_w,
        interpret=interpret,
    )
    q_cert_new, best_cert, best_src, best_slot, take, n_arr, credit_new, active = out
    return (
        q_cert_new,
        best_cert,
        best_src,
        best_slot,
        take != 0,
        n_arr,
        credit_new,
        active != 0,
    )


def queue_ingest(
    q_cert: jnp.ndarray,
    q_due: jnp.ndarray,
    q_src: jnp.ndarray,
    q_slot: jnp.ndarray,
    c_cert: jnp.ndarray,
    c_due: jnp.ndarray,
    c_src: jnp.ndarray,
    c_slot: jnp.ndarray,
    *,
    tile_w: int = 128,
    interpret: bool | None = None,
):
    """Sparse-control candidate-list ingest into the pending queues.

    Same contract as :func:`repro.kernels.ref.queue_ingest_ref` (all
    operands numeric — no boolean boundary conversion needed); returns
    ``(q_cert', q_due', q_src', q_slot')``.
    """
    if interpret is None:
        interpret = _default_interpret()
    return _queue_ingest(
        q_cert,
        q_due,
        q_src,
        q_slot,
        c_cert,
        c_due,
        c_src,
        c_slot,
        tile_w=tile_w,
        interpret=interpret,
    )


__all__ = [
    "edge_scan",
    "queue_ingest",
    "round_deliver",
    "edge_scan_batched",
    "edge_scan_sharded",
    "weight_update",
    "scatter_model_slice",
]
