"""Pallas TPU kernel for the Scanner's hot loop (paper §4.1).

The paper measures "computing the predictions of the strong rules" /
accumulating candidate edges as the dominant compute cost. On CPU
Sparrow does a scalar scatter per example; a mechanical port of that
scatter would be hostile to the TPU (no efficient scatter in VMEM).

TPU adaptation (DESIGN.md §3): recast the histogram scatter as a
*one-hot matmul* so the MXU does the accumulation —

    hist[j, b]  =  sum_i wy_i * [xb[i, j] == b]
               =  (wy^T @ P)[j, b],   P[i, (j,b)] = [xb[i,j] == b]

Each grid step loads one (tile_n, d) block of binned features into
VMEM, builds the one-hot P on the VPU, and contracts against the
weight vector on the MXU, accumulating into a resident (d, B) output
block. The stopping-rule scalars (W = sum|w|, V = sum w^2, T = sum wy)
ride along in the same pass, so one sweep over the tile produces
everything the stopping rule needs — the paper's "one scan" structure,
VMEM-tiled.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _edge_scan_kernel(xb_ref, wy_ref, w_ref, hist_ref, scal_ref, *, num_bins: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        hist_ref[...] = jnp.zeros_like(hist_ref)
        scal_ref[...] = jnp.zeros_like(scal_ref)

    xb = xb_ref[...]  # (tn, d) int32
    wy = wy_ref[...]  # (tn, 1) f32 (zero on padded rows)
    w = w_ref[...]  # (tn, 1) f32

    tn, d = xb.shape
    bins = jax.lax.broadcasted_iota(jnp.int32, (tn, d, num_bins), 2)
    p = (xb[:, :, None] == bins).astype(jnp.float32)  # one-hot (tn, d, B)
    p2 = p.reshape(tn, d * num_bins)
    # (1, tn) @ (tn, d*B) on the MXU, f32 accumulate
    g = jnp.dot(wy.reshape(1, tn), p2, preferred_element_type=jnp.float32)
    hist_ref[...] += g.reshape(d, num_bins)

    scal_ref[0, 0] += jnp.sum(jnp.abs(w))
    scal_ref[0, 1] += jnp.sum(w * w)
    scal_ref[0, 2] += jnp.sum(wy)


@functools.partial(
    jax.jit, static_argnames=("num_bins", "tile_n", "interpret")
)
def edge_scan(
    xb: jnp.ndarray,
    wy: jnp.ndarray,
    w: jnp.ndarray,
    *,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Accumulate the (feature, bin) wy-histogram + stopping-rule scalars.

    Args:
        xb: (n, d) int32 binned features.
        wy: (n,) f32 signed weights ``w_i * y_i``.
        w:  (n,) f32 weights.
        num_bins: B (static).
        tile_n: rows per grid step (VMEM tile height).
        interpret: run the kernel body in interpret mode (CPU container);
            on a real TPU pass False.

    Returns:
        (hist (d, B) f32, W (), V (), T ()).
    """
    n, d = xb.shape
    n_pad = -n % tile_n
    if n_pad:
        xb = jnp.pad(xb, ((0, n_pad), (0, 0)))
        wy = jnp.pad(wy, (0, n_pad))
        w = jnp.pad(w, (0, n_pad))
    steps = xb.shape[0] // tile_n
    wy2 = wy.reshape(-1, 1)
    w2 = w.reshape(-1, 1)

    hist, scal = pl.pallas_call(
        functools.partial(_edge_scan_kernel, num_bins=num_bins),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((d, num_bins), lambda i: (0, 0)),
            pl.BlockSpec((1, 4), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d, num_bins), jnp.float32),
            jax.ShapeDtypeStruct((1, 4), jnp.float32),
        ],
        interpret=interpret,
    )(xb, wy2, w2)
    return hist, scal[0, 0], scal[0, 1], scal[0, 2]
