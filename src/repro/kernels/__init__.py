# Pallas TPU kernels for the compute hot-spots the paper optimizes:
#   edge_scan     — the Scanner's candidate-edge accumulation (§4.1)
#   weight_update — fused incremental strong-rule re-weighting (§4.1)
# Each has a pure-jnp oracle in ref.py and a jit'd wrapper in ops.py.
# On this CPU container they run in interpret mode; TPU is the target.

from repro.kernels import ops
from repro.kernels.weight_update import scatter_model_slice

__all__ = ["ops", "scatter_model_slice"]
