"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

``tests/test_kernels.py`` sweeps shapes/dtypes and asserts the kernels
(interpret mode on CPU) match these to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.boosting.stumps import StumpModel, edge_histogram


def edge_scan_ref(
    xb: jnp.ndarray, wy: jnp.ndarray, w: jnp.ndarray, num_bins: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for :func:`repro.kernels.edge_scan.edge_scan`."""
    hist = edge_histogram(xb, wy.astype(jnp.float32), num_bins)
    W = jnp.sum(jnp.abs(w)).astype(jnp.float32)
    V = jnp.sum(w * w).astype(jnp.float32)
    T = jnp.sum(wy).astype(jnp.float32)
    return hist, W, V, T


def weight_update_ref(
    xb: jnp.ndarray,
    y: jnp.ndarray,
    margin_l: jnp.ndarray,
    margin_s: jnp.ndarray,
    a: jnp.ndarray,
    c: jnp.ndarray,
    num_bins: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for :func:`repro.kernels.weight_update.weight_update`."""
    num_cuts = num_bins - 1
    cuts = jnp.arange(num_cuts)
    p = (xb[:, :, None] > cuts[None, None, :]).astype(jnp.float32)
    delta = 2.0 * jnp.einsum("ndc,dc->n", p, a) - c
    m_new = margin_l + delta
    w = jnp.exp(jnp.clip(-y * (m_new - margin_s), -30.0, 30.0))
    return m_new, w


def round_step_ref(
    q_cert: jnp.ndarray,
    q_due: jnp.ndarray,
    q_src: jnp.ndarray,
    q_slot: jnp.ndarray,
    certs0: jnp.ndarray,
    alive: jnp.ndarray,
    credit: jnp.ndarray,
    speed_norm: jnp.ndarray,
    r: jnp.ndarray,
    *,
    eps: float,
):
    """Oracle for :func:`repro.kernels.round_step.round_step`.

    Fused sparse delivery (argmin over entries due this round, ties to
    the lowest source id — matching the dense engine's argmin), the
    eps-gated ``accepts`` test, arrival clearing, and the laggard-credit
    update. Also the engine's ``round_step_impl="ref"`` execution path,
    so it takes/returns bool masks directly (``alive`` in; ``take`` /
    ``active`` out).

    Returns ``(q_cert', best_cert, best_src, best_slot, take, n_arr,
    credit', active)``.
    """
    big = jnp.iinfo(jnp.int32).max
    arr = (q_due == r) & jnp.isfinite(q_cert)
    arr_live = jnp.where(arr & alive[:, None], q_cert, jnp.inf)
    best_cert = jnp.min(arr_live, axis=1)
    finite = jnp.isfinite(best_cert)
    hit = (arr_live == best_cert[:, None]) & finite[:, None]
    best_src = jnp.min(jnp.where(hit, q_src, big), axis=1)
    sel = hit & (q_src == best_src[:, None])
    best_slot = jnp.min(jnp.where(sel, q_slot, big), axis=1)
    best_src = jnp.where(finite, best_src, 0)
    best_slot = jnp.where(finite, best_slot, 0)
    take = finite & (best_cert < certs0 - eps)
    n_arr = jnp.sum(arr, axis=1).astype(jnp.int32)
    q_cert_new = jnp.where(arr, jnp.inf, q_cert)
    credit2 = credit + speed_norm
    active = alive & (credit2 >= 1.0 - 1e-6)
    credit_new = jnp.where(active, credit2 - 1.0, credit2)
    return q_cert_new, best_cert, best_src, best_slot, take, n_arr, credit_new, active


def queue_ingest_ref(
    q_cert: jnp.ndarray,
    q_due: jnp.ndarray,
    q_src: jnp.ndarray,
    q_slot: jnp.ndarray,
    c_cert: jnp.ndarray,
    c_due: jnp.ndarray,
    c_src: jnp.ndarray,
    c_slot: jnp.ndarray,
):
    """Oracle for :func:`repro.kernels.round_step.queue_ingest`.

    Sparse-control candidate-list ingest: merge the (W, m) candidate
    block into the (W, C) pending queues and keep the lexicographically
    smallest C entries per row by (cert, src, due) — worst-certificate-
    first eviction with the exact tie-break of the engine's
    ``_queue_push`` merge (stable lexsort: among fully tied keys the
    earlier column survives, i.e. resident queue entries beat identical
    fresh candidates).

    Returns ``(q_cert', q_due', q_src', q_slot')``.
    """
    m_cert = jnp.concatenate([q_cert, c_cert], axis=1)
    m_due = jnp.concatenate([q_due, c_due], axis=1)
    m_src = jnp.concatenate([q_src, c_src], axis=1)
    m_slot = jnp.concatenate([q_slot, c_slot], axis=1)
    cap = q_cert.shape[1]
    keep = jnp.lexsort((m_due, m_src, m_cert), axis=-1)[:, :cap]
    return (
        jnp.take_along_axis(m_cert, keep, axis=1),
        jnp.take_along_axis(m_due, keep, axis=1),
        jnp.take_along_axis(m_src, keep, axis=1),
        jnp.take_along_axis(m_slot, keep, axis=1),
    )


def margin_delta_oracle(
    model: StumpModel, xb: jnp.ndarray, t_lo: int, t_hi: int
) -> jnp.ndarray:
    """Direct stump-by-stump margin delta over slots [t_lo, t_hi) — used
    to validate ``scatter_model_slice`` + the kernel against the model
    semantics in ``repro.boosting.stumps``."""
    out = jnp.zeros((xb.shape[0],), jnp.float32)
    for k in range(t_lo, t_hi):
        h = jnp.where(xb[:, model.feat[k]] > model.thr[k], 1.0, -1.0) * model.sign[k]
        out = out + model.alpha[k] * h
    return out
