"""Pure-jnp oracles for the Pallas kernels (the correctness contract).

``tests/test_kernels.py`` sweeps shapes/dtypes and asserts the kernels
(interpret mode on CPU) match these to float tolerance.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.boosting.stumps import StumpModel, edge_histogram


def edge_scan_ref(
    xb: jnp.ndarray, wy: jnp.ndarray, w: jnp.ndarray, num_bins: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Oracle for :func:`repro.kernels.edge_scan.edge_scan`."""
    hist = edge_histogram(xb, wy.astype(jnp.float32), num_bins)
    W = jnp.sum(jnp.abs(w)).astype(jnp.float32)
    V = jnp.sum(w * w).astype(jnp.float32)
    T = jnp.sum(wy).astype(jnp.float32)
    return hist, W, V, T


def weight_update_ref(
    xb: jnp.ndarray,
    y: jnp.ndarray,
    margin_l: jnp.ndarray,
    margin_s: jnp.ndarray,
    a: jnp.ndarray,
    c: jnp.ndarray,
    num_bins: int,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Oracle for :func:`repro.kernels.weight_update.weight_update`."""
    num_cuts = num_bins - 1
    cuts = jnp.arange(num_cuts)
    p = (xb[:, :, None] > cuts[None, None, :]).astype(jnp.float32)
    delta = 2.0 * jnp.einsum("ndc,dc->n", p, a) - c
    m_new = margin_l + delta
    w = jnp.exp(jnp.clip(-y * (m_new - margin_s), -30.0, 30.0))
    return m_new, w


def margin_delta_oracle(
    model: StumpModel, xb: jnp.ndarray, t_lo: int, t_hi: int
) -> jnp.ndarray:
    """Direct stump-by-stump margin delta over slots [t_lo, t_hi) — used
    to validate ``scatter_model_slice`` + the kernel against the model
    semantics in ``repro.boosting.stumps``."""
    out = jnp.zeros((xb.shape[0],), jnp.float32)
    for k in range(t_lo, t_hi):
        h = jnp.where(xb[:, model.feat[k]] > model.thr[k], 1.0, -1.0) * model.sign[k]
        out = out + model.alpha[k] * h
    return out
