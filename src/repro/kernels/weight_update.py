"""Pallas TPU kernel for the fused incremental weight update
(paper §4.1 "Incremental Updates" / Algorithm 2 ``UPDATEWEIGHT``).

The strong-rule margin delta is recast as the same one-hot contraction
used by ``edge_scan``: scatter the model's stump slice into candidate
space *once* on the host (O(T) work),

    A[j, t]  =  sum_{k in slice: feat_k = j, thr_k = t}  alpha_k * sign_k
    c        =  sum_{k in slice}  alpha_k * sign_k

then per example the margin delta is

    H_hi(x) - H_lo(x)  =  2 * (P[i, :] @ A) - c,
    P[i, (j, t)]       =  [xb[i, j] > t]

one (tile_n, d*(B-1)) x (d*(B-1), 1) matmul per VMEM tile on the MXU,
followed by the elementwise weight epilogue on the VPU:

    margin' = margin_l + delta
    w       = exp(-y * (margin' - margin_s))        (clipped)

This removes the HBM round-trip between "compute predictions" and
"compute weights" that dominates Sparrow's CPU profile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.boosting.stumps import StumpModel

_CLIP = 30.0


def _weight_update_kernel(
    xb_ref, y_ref, ml_ref, ms_ref, a_ref, c_ref, mout_ref, wout_ref, *, num_cuts: int
):
    xb = xb_ref[...]  # (tn, d) int32
    tn, d = xb.shape
    cuts = jax.lax.broadcasted_iota(jnp.int32, (tn, d, num_cuts), 2)
    p = (xb[:, :, None] > cuts).astype(jnp.float32)  # (tn, d, B-1)
    p2 = p.reshape(tn, d * num_cuts)
    a = a_ref[...].reshape(d * num_cuts, 1)
    delta = 2.0 * jnp.dot(p2, a, preferred_element_type=jnp.float32) - c_ref[0, 0]
    m_new = ml_ref[...] + delta  # (tn, 1)
    logw = -y_ref[...] * (m_new - ms_ref[...])
    mout_ref[...] = m_new
    wout_ref[...] = jnp.exp(jnp.clip(logw, -_CLIP, _CLIP))


@functools.partial(jax.jit, static_argnames=("num_bins", "tile_n", "interpret"))
def weight_update(
    xb: jnp.ndarray,
    y: jnp.ndarray,
    margin_l: jnp.ndarray,
    margin_s: jnp.ndarray,
    a: jnp.ndarray,
    c: jnp.ndarray,
    *,
    num_bins: int,
    tile_n: int = 512,
    interpret: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Fused incremental margin + weight refresh over a block of examples.

    Args:
        xb: (n, d) int32 bins.
        y: (n,) labels +-1.
        margin_l: (n,) margins at each example's last refresh.
        margin_s: (n,) margins at sampling time.
        a: (d, B-1) scattered stump-slice coefficients (see module doc).
        c: () scalar sum of the slice's alpha*sign.
        num_bins: B (static).

    Returns:
        (margin_new (n,), w (n,)) with ``w = exp(-y (margin_new - margin_s))``.
    """
    n, d = xb.shape
    num_cuts = num_bins - 1
    n_pad = -n % tile_n
    if n_pad:
        xb = jnp.pad(xb, ((0, n_pad), (0, 0)))
        y = jnp.pad(y, (0, n_pad), constant_values=1.0)
        margin_l = jnp.pad(margin_l, (0, n_pad))
        margin_s = jnp.pad(margin_s, (0, n_pad))
    steps = xb.shape[0] // tile_n
    col = lambda v: v.reshape(-1, 1)

    m_new, w = pl.pallas_call(
        functools.partial(_weight_update_kernel, num_cuts=num_cuts),
        grid=(steps,),
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((d, num_cuts), lambda i: (0, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((xb.shape[0], 1), jnp.float32),
            jax.ShapeDtypeStruct((xb.shape[0], 1), jnp.float32),
        ],
        interpret=interpret,
    )(xb, col(y), col(margin_l), col(margin_s), a, jnp.asarray(c, jnp.float32).reshape(1, 1))
    return m_new[:n, 0], w[:n, 0]


def scatter_model_slice(
    model: StumpModel, t_lo: jnp.ndarray | int, t_hi: jnp.ndarray | int, num_bins: int, d: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Host-side O(T) prep: scatter stump slots [t_lo, t_hi) into the
    (d, B-1) candidate grid, returning (A, c) for :func:`weight_update`."""
    slot = jnp.arange(model.capacity)
    live = ((slot >= t_lo) & (slot < t_hi)).astype(jnp.float32)
    coef = model.alpha * model.sign * live
    a = jnp.zeros((d, num_bins - 1), jnp.float32).at[model.feat, model.thr].add(coef)
    return a, jnp.sum(coef)
