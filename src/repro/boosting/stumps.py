"""Decision stumps over pre-binned features, and the strong rule.

A weak rule is ``h_{j,t,s}(x) = s * (2*[bin(x_j) > t] - 1)`` for feature
``j``, bin-threshold ``t`` and sign ``s``. The strong rule is
``H(x) = sum_k alpha_k * h_k(x)`` stored as fixed-capacity arrays so the
whole model is a jit-friendly pytree (the TMSN broadcast payload).

Edges of *all* candidate stumps are computed from a single
``(features x bins)`` weighted histogram — the same trick XGBoost /
LightGBM use — so one pass over a chunk of examples updates every
candidate at once. The Pallas kernel ``repro.kernels.edge_scan``
implements the histogram accumulation for the TPU target; this module
is the pure-jnp path used on CPU and as the kernel oracle.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class StumpModel(NamedTuple):
    """Fixed-capacity strong rule (a pytree; broadcastable as-is)."""

    feat: jnp.ndarray  # (T,) int32 — feature index per stump
    thr: jnp.ndarray  # (T,) int32 — bin threshold per stump
    sign: jnp.ndarray  # (T,) float32 — +1/-1
    alpha: jnp.ndarray  # (T,) float32 — stump weight
    count: jnp.ndarray  # () int32 — number of live stumps

    @property
    def capacity(self) -> int:
        return self.feat.shape[0]


def empty_model(capacity: int) -> StumpModel:
    return StumpModel(
        feat=jnp.zeros((capacity,), jnp.int32),
        thr=jnp.zeros((capacity,), jnp.int32),
        sign=jnp.ones((capacity,), jnp.float32),
        alpha=jnp.zeros((capacity,), jnp.float32),
        count=jnp.zeros((), jnp.int32),
    )


def append_stump(
    model: StumpModel, feat: jnp.ndarray, thr: jnp.ndarray, sign: jnp.ndarray, alpha: jnp.ndarray
) -> StumpModel:
    """Append one weak rule (functional; no-op if at capacity)."""
    k = jnp.minimum(model.count, model.capacity - 1)
    ok = model.count < model.capacity
    upd = lambda a, v: a.at[k].set(jnp.where(ok, v, a[k]))
    return StumpModel(
        feat=upd(model.feat, jnp.asarray(feat, jnp.int32)),
        thr=upd(model.thr, jnp.asarray(thr, jnp.int32)),
        sign=upd(model.sign, jnp.asarray(sign, jnp.float32)),
        alpha=upd(model.alpha, jnp.asarray(alpha, jnp.float32)),
        count=model.count + jnp.asarray(ok, jnp.int32),
    )


def alpha_from_gamma(gamma: jnp.ndarray | float) -> jnp.ndarray:
    """AdaBoost weak-rule weight for a certified edge:
    ``alpha = 1/2 log((1/2 + gamma) / (1/2 - gamma))`` (Algorithm 1)."""
    g = jnp.clip(jnp.asarray(gamma, jnp.float32), -0.49, 0.49)
    return 0.5 * jnp.log((0.5 + g) / (0.5 - g))


def _stump_preds(model: StumpModel, xb: jnp.ndarray) -> jnp.ndarray:
    """(n, T) predictions of every stored stump on binned rows ``xb``."""
    gathered = xb[:, model.feat]  # (n, T)
    return jnp.where(gathered > model.thr[None, :], 1.0, -1.0) * model.sign[None, :]


def predict_margin(model: StumpModel, xb: jnp.ndarray) -> jnp.ndarray:
    """Full strong-rule margin ``H(x)`` for binned rows ``xb`` (n, d)."""
    preds = _stump_preds(model, xb)  # (n, T)
    live = (jnp.arange(model.capacity) < model.count).astype(jnp.float32)
    return preds @ (model.alpha * live)


def predict_margin_delta(
    model: StumpModel, xb: jnp.ndarray, t_from: jnp.ndarray
) -> jnp.ndarray:
    """Incremental margin: ``H_t(x) - H_{t_from}(x)`` per example.

    ``t_from`` is per-example (n,) — the stump count at the example's
    last weight refresh (paper §4.1 "Incremental Updates": Scanner and
    Sampler share the burden of computing the weights).
    """
    preds = _stump_preds(model, xb)  # (n, T)
    slot = jnp.arange(model.capacity)[None, :]
    live = (slot >= t_from[:, None]) & (slot < model.count)
    return jnp.sum(preds * model.alpha[None, :] * live.astype(jnp.float32), axis=1)


def exp_loss(model: StumpModel, xb: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    """Average exponential-loss potential ``Z_S(H)`` (paper §3)."""
    return jnp.mean(jnp.exp(-y * predict_margin(model, xb)))


def error_rate(model: StumpModel, xb: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    margin = predict_margin(model, xb)
    pred = jnp.where(margin >= 0, 1.0, -1.0)
    return jnp.mean(pred != y)


def model_payload_bytes(model: StumpModel) -> int:
    """Broadcast payload size of a strong rule (for comm accounting)."""
    return sum(int(x.size * x.dtype.itemsize) for x in model)


# --------------------------------------------------------------------------
# Candidate-edge machinery: one (d, B) weighted histogram covers every
# candidate stump.
# --------------------------------------------------------------------------


def edge_histogram(xb: jnp.ndarray, wy: jnp.ndarray, num_bins: int) -> jnp.ndarray:
    """Scatter-add ``wy`` into per-(feature, bin) cells.

    Args:
        xb: (n, d) int bins.
        wy: (n,) signed weights ``w_i * y_i``.
        num_bins: B.

    Returns:
        (d, B) float32 histogram; ``hist[j, b] = sum_{i: xb[i,j]=b} wy_i``.
    """
    n, d = xb.shape
    hist = jnp.zeros((d, num_bins), jnp.float32)
    cols = jnp.broadcast_to(jnp.arange(d)[None, :], (n, d))
    return hist.at[cols, xb].add(wy[:, None])


def edges_from_histogram(hist: jnp.ndarray) -> jnp.ndarray:
    """Per-candidate signed edge mass from a wy-histogram.

    ``m[j, t] = sum_i wy_i h_{j,t}(x_i) = 2 * G_j(t) - T`` where
    ``G_j(t) = sum_{b > t} hist[j, b]`` and ``T = sum_i wy_i``.

    Returns (d, B-1): thresholds t in [0, B-2].
    """
    total = jnp.sum(hist, axis=1, keepdims=True)  # = sum_i wy_i, per feature row
    # suffix sums over bins strictly greater than t
    rev_cum = jnp.cumsum(hist[:, ::-1], axis=1)[:, ::-1]  # G_j(t-1) = sum_{b>=t}
    g = rev_cum[:, 1:]  # G_j(t) for t = 0..B-2
    return 2.0 * g - total


def best_stump_exact(
    xb: jnp.ndarray, y: jnp.ndarray, w: jnp.ndarray, num_bins: int
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Exact greedy best stump over the full weighted set.

    Returns (feat, thr, sign, gamma_hat) where ``gamma_hat`` is the
    empirical (normalized) edge of the chosen stump.
    """
    wy = w * y
    hist = edge_histogram(xb, wy, num_bins)
    m = edges_from_histogram(hist)  # (d, B-1)
    W = jnp.sum(jnp.abs(w))
    flat = jnp.abs(m).ravel()
    idx = jnp.argmax(flat)
    feat = idx // m.shape[1]
    thr = idx % m.shape[1]
    raw = m[feat, thr]
    sign = jnp.where(raw >= 0, 1.0, -1.0)
    gamma_hat = jnp.abs(raw) / jnp.maximum(W, 1e-30) / 2.0
    return feat.astype(jnp.int32), thr.astype(jnp.int32), sign, gamma_hat


def bin_features(x: jnp.ndarray, num_bins: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Quantile-bin raw float features into int32 bins.

    Returns (bins (n,d) int32, cut_points (d, B-1)). This is the usual
    GBDT pre-processing step (XGBoost approximate greedy / LightGBM
    histograms); Sparrow's stumps operate on the same binned view.
    """
    qs = jnp.linspace(0.0, 1.0, num_bins + 1)[1:-1]
    cuts = jnp.quantile(x, qs, axis=0).T  # (d, B-1)
    bins = jnp.sum(x[:, :, None] > cuts[None, :, :], axis=2).astype(jnp.int32)
    return bins, cuts
