"""Baselines the paper compares against, rebuilt in JAX on the same
stump/histogram substrate so the comparison is apples-to-apples:

* ``train_exact_greedy`` — XGBoost-style in-memory exact greedy: every
  boosting iteration scans the FULL training set, builds the (feature,
  bin) gradient histogram, and takes the best stump. (XGBoost's
  "approximate greedy" quantile sketch == our shared pre-binning.)
* ``train_goss`` — LightGBM-style Gradient-based One-Side Sampling:
  keep the top-``a`` fraction by |gradient| (== AdaBoost weight), sample
  a ``b`` fraction of the rest and up-weight it by ``(1-a)/b``; build the
  histogram only on the subset. Gradients are still refreshed for all n
  examples each iteration (as LightGBM does).
* ``train_adaboost_reference`` — textbook synchronous AdaBoost with the
  empirically-optimal alpha; correctness oracle for tests.

All three share Sparrow's cost model (examples touched +
STUMP_EVAL_COST * incremental stump evals) so "simulated seconds" are
comparable across systems.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.boosting.sparrow import STUMP_EVAL_COST
from repro.boosting.stumps import (
    StumpModel,
    alpha_from_gamma,
    append_stump,
    best_stump_exact,
    empty_model,
)


@dataclasses.dataclass(frozen=True)
class BoosterConfig:
    num_rounds: int = 100
    num_bins: int = 32
    capacity: int = 256
    # GOSS fractions (LightGBM defaults: a=0.2, b=0.1)
    goss_top: float = 0.2
    goss_rest: float = 0.1
    seed: int = 0
    eval_every: int = 5


class BoostTrace(NamedTuple):
    """(cost, metric) checkpoints for the loss-vs-time figures."""

    cost: list  # cumulative cost units at each checkpoint
    rounds: list
    metric: list  # eval_fn(model) at each checkpoint
    model: StumpModel


EvalFn = Callable[[StumpModel], float]


def _loop(
    xb: jnp.ndarray,
    y: jnp.ndarray,
    cfg: BoosterConfig,
    eval_fn: EvalFn | None,
    step_fn: Callable[[StumpModel, jnp.ndarray, jax.Array], tuple[StumpModel, float]],
) -> BoostTrace:
    """Common driver: maintains margins incrementally, charges cost."""
    n = xb.shape[0]
    model = empty_model(cfg.capacity)
    margin = jnp.zeros((n,), jnp.float32)
    key = jax.random.PRNGKey(cfg.seed)
    cost = 0.0
    costs, rounds, metrics = [], [], []
    for r in range(cfg.num_rounds):
        w = jnp.exp(jnp.clip(-y * margin, -30.0, 30.0))
        key, sub = jax.random.split(key)
        prev_count = int(model.count)
        model, step_cost = step_fn(model, w, sub)
        cost += step_cost
        if int(model.count) > prev_count:
            # incremental margin refresh: one new stump on all n examples
            k = prev_count
            f, t, s, a = model.feat[k], model.thr[k], model.sign[k], model.alpha[k]
            h = jnp.where(xb[:, f] > t, 1.0, -1.0) * s
            margin = margin + a * h
            cost += STUMP_EVAL_COST * n
        if eval_fn is not None and (r % cfg.eval_every == 0 or r == cfg.num_rounds - 1):
            costs.append(cost)
            rounds.append(r + 1)
            metrics.append(float(eval_fn(model)))
    return BoostTrace(cost=costs, rounds=rounds, metric=metrics, model=model)


def train_exact_greedy(
    xb: jnp.ndarray, y: jnp.ndarray, cfg: BoosterConfig, eval_fn: EvalFn | None = None
) -> BoostTrace:
    """XGBoost-like: full-scan exact greedy per round."""
    n = xb.shape[0]

    def step(model: StumpModel, w: jnp.ndarray, key: jax.Array) -> tuple[StumpModel, float]:
        feat, thr, sign, gamma_hat = best_stump_exact(xb, y, w, cfg.num_bins)
        alpha = alpha_from_gamma(gamma_hat)
        model = append_stump(model, feat, thr, sign, alpha)
        return model, float(n)  # one full histogram pass

    return _loop(xb, y, cfg, eval_fn, step)


def train_goss(
    xb: jnp.ndarray, y: jnp.ndarray, cfg: BoosterConfig, eval_fn: EvalFn | None = None
) -> BoostTrace:
    """LightGBM-like GOSS: histogram on top-a + sampled-b subset."""
    n = xb.shape[0]
    k_top = max(1, int(cfg.goss_top * n))
    k_rest = max(1, int(cfg.goss_rest * n))
    amplify = (1.0 - cfg.goss_top) / (cfg.goss_rest)

    def step(model: StumpModel, w: jnp.ndarray, key: jax.Array) -> tuple[StumpModel, float]:
        order = jnp.argsort(-w)
        top = order[:k_top]
        rest_pool = order[k_top:]
        pick = jax.random.choice(key, rest_pool, shape=(k_rest,), replace=False)
        idx = jnp.concatenate([top, pick])
        w_sub = jnp.concatenate([w[top], w[pick] * amplify])
        feat, thr, sign, gamma_hat = best_stump_exact(
            xb[idx], y[idx], w_sub, cfg.num_bins
        )
        alpha = alpha_from_gamma(gamma_hat)
        model = append_stump(model, feat, thr, sign, alpha)
        # gradients refreshed for all n (cheap pass) + histogram on subset
        return model, float(k_top + k_rest) + 0.2 * n

    return _loop(xb, y, cfg, eval_fn, step)


def train_adaboost_reference(
    xb: jnp.ndarray, y: jnp.ndarray, cfg: BoosterConfig, eval_fn: EvalFn | None = None
) -> BoostTrace:
    """Textbook AdaBoost (the correctness oracle; same as exact greedy
    here since both use the empirically best stump + optimal alpha)."""
    return train_exact_greedy(xb, y, cfg, eval_fn)
