"""Batched Sparrow: W workers as stacked ``(W, ...)`` pytrees.

The per-worker computation is exactly :mod:`repro.boosting.sparrow`'s
scan/fire/resample/adopt logic, re-expressed so every branch is an
elementwise select and the chunk scan is ``vmap(scan_chunk)`` over the
worker axis — including the Pallas ``kernels/edge_scan`` path when
``ScannerConfig.use_kernel`` is set (``vmap`` of a ``pallas_call``
prepends a batch grid dimension, so all W histogram accumulations run
in one kernel launch).

Plugged into :class:`repro.core.engine.TMSNEngine` this advances all W
workers one segment per round in a single jitted computation; the
event-driven simulator with the unbatched :class:`SparrowWorker`
remains the fidelity-1 oracle (``tests/test_engine.py`` pins the
per-segment equivalence of the two).

The same methods trace inside the sharded engine's shard-mapped round
step, where the leading axis is the *local* worker count: everything
per-worker (including the feature-ownership masks) lives in the state
pytree and shards with it, while the disk dataset (``xb``/``y``) is a
closed-over shared read-only reference, replicated per device exactly
as the paper's shared-disk model prescribes.

Deviations from the unbatched worker, both bounded and test-pinned:

  * adoption cost is charged on the round it happens instead of via
    ``pending_cost`` on the next segment (same totals, simpler state);
  * Python-float certificate accumulation becomes float32 array math
    (differences are at the 1e-6 level).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.boosting.scanner import (
    SampleState,
    ScannerState,
    init_scanner,
    reset_after_fire,
    reset_after_fruitless_pass,
    scan_chunk,
)
from repro.boosting.sparrow import (
    STUMP_EVAL_COST,
    SparrowWorkerBase,
    draw_sample,
)
from repro.core.ess import effective_sample_size
from repro.core.worker import masked_rows
from repro.boosting.stumps import (
    StumpModel,
    alpha_from_gamma,
    append_stump,
    empty_model,
    model_payload_bytes,
    predict_margin_delta,
)


class BatchedSparrowState(NamedTuple):
    """Stacked per-worker state; every leaf has a leading (W,) axis.

    Per-worker *constants* (the feature-ownership masks) live here too,
    not on the worker object: inside the sharded engine's shard-mapped
    round step each device sees only its local slice of the state, so
    anything indexed by worker identity must shard along with it — a
    closed-over ``(W, d)`` array would arrive fully replicated and
    misaligned with the ``(W_local, ...)`` leaves.
    """

    model: StumpModel  # fields (W, T), count (W,)
    cert: jnp.ndarray  # (W,) f32
    scanner: ScannerState  # leaves (W, ...)
    sample: SampleState  # leaves (W, m, ...)
    disk_margin: jnp.ndarray  # (W, n)
    disk_t: jnp.ndarray  # (W, n) i32
    key: jax.Array  # (W, 2) PRNG keys
    needs_resample: jnp.ndarray  # (W,) bool
    fires: jnp.ndarray  # (W,) i32
    resamples: jnp.ndarray  # (W,) i32
    sample_model_count: jnp.ndarray  # (W,) i32
    scan_since_resample: jnp.ndarray  # (W,) f32
    feat_mask: jnp.ndarray  # (W, d) bool — feature ownership (constant)


# per-worker select over a stacked pytree — the contract-level helper
# from repro.core.worker, kept under its historical local name
_bwhere = masked_rows


def common_prefix_len(a: StumpModel, b: StumpModel) -> jnp.ndarray:
    """Jit-safe length of the shared stump prefix of two (unbatched)
    models (the traced counterpart of ``SparrowWorker._common_prefix``)."""
    same = (
        (a.feat == b.feat)
        & (a.thr == b.thr)
        & (a.sign == b.sign)
        & (a.alpha == b.alpha)
    )
    slots = jnp.arange(a.capacity)
    same = same & (slots < jnp.minimum(a.count, b.count))
    return jnp.sum(jnp.cumprod(same.astype(jnp.int32))).astype(jnp.int32)


class BatchedSparrowWorker(SparrowWorkerBase):
    """Implements :class:`repro.core.worker.BatchedTMSNWorker` for
    Sparrow — the boosting instantiation of the worker contract."""

    # ----- engine protocol hooks --------------------------------------
    def init_batch(self, n_workers: int, seed: int) -> BatchedSparrowState:
        cfg = self.config
        if n_workers != cfg.n_workers:
            raise ValueError(f"engine W={n_workers} != SparrowConfig.n_workers={cfg.n_workers}")
        # same per-worker streams as TMSNSimulator: PRNGKey(seed + 1000*i)
        keys = jnp.stack([jax.random.PRNGKey(seed + 1000 * i) for i in range(n_workers)])

        def _init_one(key: jax.Array):
            model = empty_model(cfg.capacity)
            disk_margin = jnp.zeros((self.n,), jnp.float32)
            key, sub = jax.random.split(key)
            sample = draw_sample(sub, self.xb, self.y, model, disk_margin, cfg.sample_size)
            return model, sample, key

        model, sample, keys = jax.vmap(_init_one)(keys)
        scanner = jax.vmap(lambda _: init_scanner(self.d, cfg.scanner))(
            jnp.arange(n_workers)
        )
        zeros_i = jnp.zeros((n_workers,), jnp.int32)
        return BatchedSparrowState(
            model=model,
            cert=jnp.zeros((n_workers,), jnp.float32),
            scanner=scanner,
            sample=sample,
            disk_margin=jnp.zeros((n_workers, self.n), jnp.float32),
            disk_t=jnp.zeros((n_workers, self.n), jnp.int32),
            key=keys,
            needs_resample=jnp.zeros((n_workers,), bool),
            fires=zeros_i,
            resamples=zeros_i,
            sample_model_count=zeros_i,
            scan_since_resample=jnp.zeros((n_workers,), jnp.float32),
            feat_mask=self._feat_masks,
        )

    def certificates(self, state: BatchedSparrowState) -> jnp.ndarray:
        return state.cert

    def export_models(self, state: BatchedSparrowState) -> StumpModel:
        return state.model

    def export_payload_rows(
        self, state: BatchedSparrowState, rows: jnp.ndarray
    ) -> StumpModel:
        """Gather just ``rows`` of the broadcast payload — the sharded
        engine's candidate-selecting tiers both use this hook: gated
        intra-pod gossip ships each device's top-k improved candidate
        models instead of the full (W_local, ...) stack, and the
        pod-mesh engine's cross-pod (DCN) tier ships each device's
        top-k *pending* candidates every ``cross_pod_every_k`` rounds.
        The rows carry whatever the worker currently holds, so a
        cross-pod flush always exports the FRESHEST model for a worker
        whose certificate kept improving between flushes."""
        return jax.tree_util.tree_map(lambda a: a[rows], state.model)

    def needs_resample(self, state: BatchedSparrowState) -> jnp.ndarray:
        return state.needs_resample

    def payload_bytes(self) -> int:
        return model_payload_bytes(empty_model(self.config.capacity))

    # ----- one scan segment for every masked worker -------------------
    def scan_round(
        self, state: BatchedSparrowState, mask: jnp.ndarray
    ) -> tuple[BatchedSparrowState, jnp.ndarray, jnp.ndarray]:
        cfg = self.config
        m = cfg.sample_size
        scan = functools.partial(scan_chunk, config=cfg.scanner)
        scanner_s, sample_s, info = jax.vmap(scan)(
            state.scanner, state.sample, state.model, state.feat_mask
        )
        chunk = min(cfg.scanner.chunk_size, m)
        maskf = mask.astype(jnp.float32)
        cost = (chunk * cfg.mem_read_cost + STUMP_EVAL_COST * info.stump_evals) * maskf

        # --- fire: append the certified stump, advance the certificate ---
        gamma = info.cert_gamma
        alpha = alpha_from_gamma(gamma)
        model2 = jax.vmap(append_stump)(state.model, info.feat, info.thr, info.sign, alpha)
        grew = model2.count > state.model.count
        fired = info.fired & mask & grew  # at capacity: no growth, no certificate claim
        cert = jnp.where(
            fired, state.cert + 0.5 * jnp.log1p(-4.0 * jnp.square(gamma)), state.cert
        )
        model = _bwhere(fired, model2, state.model)

        fire_scanner = jax.vmap(
            lambda s, g: reset_after_fire(s, cfg.keep_gamma_on_fire, cfg.scanner, g)
        )(scanner_s, info.emp_gamma)
        fruitless = (~info.fired) & info.full_pass & mask
        fruitless_scanner = jax.vmap(reset_after_fruitless_pass)(scanner_s)
        scanner = _bwhere(
            fired, fire_scanner, _bwhere(fruitless, fruitless_scanner, scanner_s)
        )

        # --- ESS staleness / gamma-exhaustion -> schedule resample ---
        wts = jnp.exp(
            jnp.clip(-sample_s.y * (sample_s.margin_l - sample_s.margin_s), -30.0, 30.0)
        )
        ess = jax.vmap(effective_sample_size)(wts)
        stale = ess / m < cfg.ess_threshold
        advanced = state.model.count > state.sample_model_count
        exhausted = (scanner_s.gamma <= 2e-4) & advanced
        needs = jnp.where(
            fired, stale, jnp.where(fruitless, stale | exhausted, state.needs_resample)
        )

        new_state = state._replace(
            model=model,
            cert=cert,
            scanner=scanner,
            sample=sample_s,
            needs_resample=needs,
            fires=state.fires + fired.astype(jnp.int32),
            scan_since_resample=state.scan_since_resample + cost,
        )
        # masked-out workers come back untouched
        new_state = _bwhere(mask, new_state, state)
        return new_state, cost, fired

    # ----- resample segment (rare; sequential over workers so the full
    # disk pass never materializes a (W, n, T) intermediate) ------------
    def resample_round(
        self, state: BatchedSparrowState, do: jnp.ndarray
    ) -> tuple[BatchedSparrowState, jnp.ndarray]:
        cfg = self.config

        def _resample_one(st: BatchedSparrowState):
            delta = predict_margin_delta(st.model, self.xb, st.disk_t)
            evals = jnp.sum(
                jnp.minimum(st.model.count - st.disk_t, st.model.capacity)
            ).astype(jnp.float32)
            disk_margin = st.disk_margin + delta
            disk_t = jnp.full_like(st.disk_t, st.model.count)
            key, sub = jax.random.split(st.key)
            sample = draw_sample(sub, self.xb, self.y, st.model, disk_margin, cfg.sample_size)
            cost = self.n * cfg.disk_read_cost + STUMP_EVAL_COST * evals
            if cfg.parallel_sampler:
                cost = jnp.maximum(cost - st.scan_since_resample, 0.0)
            scanner = reset_after_fire(st.scanner, True, cfg.scanner)._replace(
                pos=jnp.zeros((), jnp.int32)
            )
            new = st._replace(
                sample=sample,
                disk_margin=disk_margin,
                disk_t=disk_t,
                key=key,
                needs_resample=jnp.zeros((), bool),
                scanner=scanner,
                resamples=st.resamples + 1,
                sample_model_count=st.model.count,
                scan_since_resample=jnp.zeros((), jnp.float32),
            )
            return new, jnp.asarray(cost, jnp.float32)

        def _one(per):
            st, flag = per
            return jax.lax.cond(
                flag, _resample_one, lambda s: (s, jnp.zeros((), jnp.float32)), st
            )

        new_state, cost = jax.lax.map(_one, (state, do))
        return new_state, cost

    # ----- adoption (interrupt + replace (H, L)) -----------------------
    def adopt_batch(
        self,
        state: BatchedSparrowState,
        models: StumpModel,
        certs: jnp.ndarray,
        take: jnp.ndarray,
    ) -> tuple[BatchedSparrowState, jnp.ndarray]:
        """Vectorized counterpart of ``SparrowWorker.adopt``: incremental
        margin transfer across the shared stump prefix, elementwise."""
        cfg = self.config
        m = cfg.sample_size

        def _adopt_one(st: BatchedSparrowState, new_model: StumpModel, new_cert):
            p = common_prefix_len(st.model, new_model)
            xb = st.sample.xb
            catchup = predict_margin_delta(st.model, xb, st.sample.t_l)
            evals = jnp.sum(
                jnp.clip(st.model.count - st.sample.t_l, 0, None)
            ).astype(jnp.float32)
            full_old = st.sample.margin_l + catchup
            pfx = jnp.full((m,), p, jnp.int32)
            old_sfx = predict_margin_delta(st.model, xb, pfx)
            new_sfx = predict_margin_delta(new_model, xb, pfx)
            m_new = full_old - old_sfx + new_sfx
            evals += (m * ((st.model.count - p) + (new_model.count - p))).astype(jnp.float32)
            sample = st.sample._replace(
                margin_l=m_new,
                t_l=jnp.full_like(st.sample.t_l, new_model.count),
            )
            keep_disk = p >= st.disk_t[0]
            disk_margin = jnp.where(keep_disk, st.disk_margin, 0.0)
            disk_t = jnp.where(keep_disk, st.disk_t, 0)
            cost = STUMP_EVAL_COST * evals * cfg.mem_read_cost
            new = st._replace(
                model=new_model,
                cert=jnp.asarray(new_cert, jnp.float32),
                sample=sample,
                disk_margin=disk_margin,
                disk_t=disk_t,
                scanner=reset_after_fire(st.scanner, True, cfg.scanner),
            )
            return new, cost

        adopted, cost = jax.vmap(_adopt_one)(state, models, certs)
        new_state = _bwhere(take, adopted, state)
        return new_state, cost * take.astype(jnp.float32)
