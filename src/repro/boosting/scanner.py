"""The Scanner (paper §4.1, Algorithm 2).

Reads the in-memory sample cyclically in *chunks* (our interruption /
check granularity — the paper checks the stopping rule per example; a
chunk is the TPU/vector-friendly equivalent and is conservative: we can
only fire later than the paper would, never earlier on less evidence).

Per chunk it:
  1. lazily refreshes example weights (incremental update from each
     example's last-touched stump count ``t_l`` — paper's
     ``UPDATEWEIGHT``),
  2. scatter-adds ``w*y`` into the (feature, bin) histogram,
  3. re-derives every candidate's edge mass and applies the
     iterated-logarithm stopping rule.

State is a pytree; the chunk step is jittable.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.stopping import StoppingRuleParams, stopping_rule_fires
from repro.boosting.stumps import (
    StumpModel,
    edge_histogram,
    edges_from_histogram,
    predict_margin_delta,
)


class ScannerConfig(NamedTuple):
    chunk_size: int = 2048
    num_bins: int = 32
    gamma0: float = 0.25
    #: scan budget per gamma level, as a multiple of the sample size m;
    #: exceeding it halves gamma (Algorithm 2: ``if m > M``).
    budget_mult: float = 4.0
    C: float = 1.0
    delta: float = 1e-6
    #: route histogram accumulation through the Pallas edge_scan kernel
    #: (interpret mode on CPU; compiled Mosaic on a real TPU).
    use_kernel: bool = False
    #: gamma policy after a successful fire:
    #:   "keep"  - pseudocode: stay at the collapsed level (tiny alphas),
    #:   "track" - next target = 0.75 x the fired rule's EMPIRICAL edge
    #:             (follows the decaying edge sequence without fruitless
    #:             passes; what the released Sparrow effectively does)
    gamma_policy: str = "track"

    @property
    def rule_params(self) -> StoppingRuleParams:
        return StoppingRuleParams(C=self.C, delta=self.delta)


class ScannerState(NamedTuple):
    hist: jnp.ndarray  # (d, B) f32 accumulated wy histogram
    W: jnp.ndarray  # () f32 total |w| scanned
    V: jnp.ndarray  # () f32 total w^2 scanned
    pos: jnp.ndarray  # () i32 cursor into the sample
    n_scanned: jnp.ndarray  # () i32 examples since last fire/reset
    budget_used: jnp.ndarray  # () i32 examples since gamma level start
    gamma: jnp.ndarray  # () f32 current target edge


class SampleState(NamedTuple):
    """The in-memory sample with lazy-weight bookkeeping (paper's
    per-example tuple ``(x, y, w_s, w_l, H_l)`` in margin form)."""

    xb: jnp.ndarray  # (m, d) i32 binned features
    y: jnp.ndarray  # (m,) f32 labels +-1
    margin_s: jnp.ndarray  # (m,) f32 H(x) at sampling time (w_s = exp(-y*margin_s))
    margin_l: jnp.ndarray  # (m,) f32 latest computed margin
    t_l: jnp.ndarray  # (m,) i32 stump count at latest margin refresh


class FireInfo(NamedTuple):
    fired: jnp.ndarray  # () bool
    feat: jnp.ndarray  # () i32
    thr: jnp.ndarray  # () i32
    sign: jnp.ndarray  # () f32
    gamma: jnp.ndarray  # () f32 certified target edge at fire time
    cert_gamma: jnp.ndarray  # () f32 sound lower confidence bound on the edge
    emp_gamma: jnp.ndarray  # () f32 empirical edge of the fired rule
    full_pass: jnp.ndarray  # () bool — completed a cycle without firing
    stump_evals: jnp.ndarray  # () f32 — incremental-update work done (cost model)


def init_scanner(num_features: int, config: ScannerConfig) -> ScannerState:
    return ScannerState(
        hist=jnp.zeros((num_features, config.num_bins), jnp.float32),
        W=jnp.zeros((), jnp.float32),
        V=jnp.zeros((), jnp.float32),
        pos=jnp.zeros((), jnp.int32),
        n_scanned=jnp.zeros((), jnp.int32),
        budget_used=jnp.zeros((), jnp.int32),
        gamma=jnp.asarray(config.gamma0, jnp.float32),
    )


def reset_after_fire(
    state: ScannerState,
    keep_gamma: bool,
    config: ScannerConfig,
    emp_gamma: jnp.ndarray | float | None = None,
) -> ScannerState:
    """Clear accumulators after a weak rule is added (or adopted)."""
    if not keep_gamma:
        gamma = jnp.asarray(config.gamma0, jnp.float32)
    elif config.gamma_policy == "track" and emp_gamma is not None:
        gamma = jnp.clip(jnp.asarray(emp_gamma) * 0.75, 1e-4, config.gamma0)
    else:
        gamma = state.gamma
    return ScannerState(
        hist=jnp.zeros_like(state.hist),
        W=jnp.zeros_like(state.W),
        V=jnp.zeros_like(state.V),
        pos=state.pos,
        n_scanned=jnp.zeros_like(state.n_scanned),
        budget_used=jnp.zeros_like(state.budget_used),
        gamma=gamma,
    )


def reset_after_fruitless_pass(state: ScannerState) -> ScannerState:
    """A full cycle without firing: the target edge is too ambitious for
    this sample. Halve gamma and clear the accumulators (each scanner
    "invocation" must see each example at most once, or the martingale
    evidence double-counts).

    Deviation from Algorithm 1 (documented in DESIGN.md): the pseudocode
    returns Fail and unconditionally resamples, which deadlocks when the
    model has not changed since sampling (the fresh sample is
    distributionally identical and the scanner fails forever at the same
    gamma). We halve gamma here and let the worker resample only when
    the model advanced since the last sample.
    """
    return ScannerState(
        hist=jnp.zeros_like(state.hist),
        W=jnp.zeros_like(state.W),
        V=jnp.zeros_like(state.V),
        pos=state.pos,
        n_scanned=jnp.zeros_like(state.n_scanned),
        budget_used=jnp.zeros_like(state.budget_used),
        gamma=state.gamma * 0.5,
    )


@functools.partial(jax.jit, static_argnames=("config",))
def scan_chunk(
    scanner: ScannerState,
    sample: SampleState,
    model: StumpModel,
    feat_mask: jnp.ndarray,
    config: ScannerConfig,
) -> tuple[ScannerState, SampleState, FireInfo]:
    """Process one chunk of the in-memory sample.

    Args:
        feat_mask: (d,) bool — features this worker owns (feature-based
            parallelization, paper §4). Candidates on un-owned features
            never fire.
    """
    m = sample.xb.shape[0]
    c = config.chunk_size
    offs = jnp.arange(c, dtype=jnp.int32)
    # Do not scan past a full cycle: mask examples beyond it.
    remaining = jnp.maximum(m - scanner.n_scanned, 0)
    valid = offs < remaining
    idx = (scanner.pos + offs) % m

    xb_c = sample.xb[idx]  # (c, d)
    y_c = sample.y[idx]

    # --- lazy incremental weight refresh (UPDATEWEIGHT) ---
    t_from = sample.t_l[idx]
    delta = predict_margin_delta(model, xb_c, t_from)  # (c,)
    margin_new = sample.margin_l[idx] + delta
    # scan weight = w_latest / w_sampled = exp(-y (H(x) - H_s(x)))
    logw = -y_c * (margin_new - sample.margin_s[idx])
    w = jnp.exp(jnp.clip(logw, -30.0, 30.0)) * valid.astype(jnp.float32)
    stump_evals = jnp.sum(
        jnp.minimum(model.count - t_from, model.capacity) * valid, dtype=jnp.float32
    )

    sample = sample._replace(
        margin_l=sample.margin_l.at[idx].set(
            jnp.where(valid, margin_new, sample.margin_l[idx])
        ),
        t_l=sample.t_l.at[idx].set(jnp.where(valid, model.count, sample.t_l[idx])),
    )

    # --- accumulate histogram + scalars ---
    wy = w * y_c
    if config.use_kernel:
        from repro.kernels import ops as kops

        h_k, W_k, V_k, _ = kops.edge_scan(
            xb_c, wy, w, num_bins=config.num_bins, tile_n=min(c, 512)
        )
        hist = scanner.hist + h_k
        W = scanner.W + W_k
        V = scanner.V + V_k
    else:
        hist = scanner.hist + edge_histogram(xb_c, wy, config.num_bins)
        W = scanner.W + jnp.sum(jnp.abs(w))
        V = scanner.V + jnp.sum(w * w)
    n_new = jnp.sum(valid, dtype=jnp.int32)
    n_scanned = scanner.n_scanned + n_new
    budget_used = scanner.budget_used + n_new

    # --- budget check: halve gamma when the level's budget is exhausted ---
    budget = jnp.asarray(config.budget_mult * m, jnp.int32)
    over = budget_used > budget
    gamma = jnp.where(over, scanner.gamma * 0.5, scanner.gamma)
    budget_used = jnp.where(over, 0, budget_used)

    # --- stopping rule over every candidate ---
    edges = edges_from_histogram(hist)  # (d, B-1)
    fires, signs, rule_score = stopping_rule_fires(edges, W, V, gamma, config.rule_params)
    fires = fires & feat_mask[:, None]
    # pick the strongest firing candidate: largest statistic - threshold
    score = jnp.where(fires, rule_score, -jnp.inf)
    flat = score.ravel()
    best = jnp.argmax(flat)
    fired = jnp.isfinite(flat[best])
    nb = edges.shape[1]
    feat = (best // nb).astype(jnp.int32)
    thr = (best % nb).astype(jnp.int32)
    sign = signs[feat, thr]
    emp_gamma = jnp.abs(edges[feat, thr]) / jnp.maximum(2.0 * W, 1e-9)
    # Sound lower CONFIDENCE bound on the fired rule's edge: the LIL
    # bound |m - mu*W| <= thr holds uniformly in t, so
    #   mu >= (|m| - thr) / W   =>   gamma_lb = (|m| - thr) / (2W)
    # (tighter than the tested target gamma; alpha is set from this).
    M_best = jnp.abs(edges[feat, thr]) - 2.0 * gamma * W
    thr_best = M_best - rule_score[feat, thr]  # threshold at fire time
    cert_gamma = (jnp.abs(edges[feat, thr]) - thr_best) / jnp.maximum(2.0 * W, 1e-9)
    cert_gamma = jnp.clip(cert_gamma, gamma, 0.49)

    full_pass = (~fired) & (n_scanned >= m)

    new_scanner = ScannerState(
        hist=hist,
        W=W,
        V=V,
        pos=(scanner.pos + n_new) % m,
        n_scanned=n_scanned,
        budget_used=budget_used,
        gamma=gamma,
    )
    info = FireInfo(
        fired=fired,
        feat=feat,
        thr=thr,
        sign=sign,
        gamma=gamma,
        cert_gamma=cert_gamma,
        emp_gamma=emp_gamma,
        full_pass=full_pass,
        stump_evals=stump_evals,
    )
    return new_scanner, sample, info
