"""The Sampler (paper §4.1): selective sampling with probability
proportional to weight, producing a fresh uniform-weight sample.

The paper uses *minimal variance sampling* (Kitagawa 1996, a.k.a.
systematic resampling) rather than per-example rejection sampling,
"because it produces less variation in the sampled set". Both are
implemented; rejection sampling exists for the ablation in
EXPERIMENTS.md.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def minimal_variance_sample(
    key: jax.Array, w: jnp.ndarray, m: int
) -> jnp.ndarray:
    """Systematic (minimal-variance) resampling.

    Draws ``m`` indices with inclusion counts ``floor(m*p_i)`` or
    ``ceil(m*p_i)`` where ``p_i = w_i / sum(w)`` — the minimum-variance
    unbiased scheme. A single uniform offset decides every pick.

    Returns int32 indices of shape (m,) (may repeat heavy examples).
    """
    w = jnp.maximum(jnp.asarray(w, jnp.float32), 0.0)
    total = jnp.sum(w)
    # Degenerate all-zero weights: fall back to uniform.
    p = jnp.where(total > 0, w / jnp.maximum(total, 1e-30), 1.0 / w.shape[0])
    cum = jnp.cumsum(p)
    u0 = jax.random.uniform(key)
    points = (jnp.arange(m, dtype=jnp.float32) + u0) / m
    idx = jnp.searchsorted(cum, points, side="left")
    return jnp.clip(idx, 0, w.shape[0] - 1).astype(jnp.int32)


def rejection_sample(
    key: jax.Array, w: jnp.ndarray, m: int
) -> jnp.ndarray:
    """Rejection-style weighted sampling (with replacement) — the
    "best known" alternative the paper mentions. Higher variance in
    inclusion counts than minimal-variance sampling."""
    w = jnp.maximum(jnp.asarray(w, jnp.float32), 0.0)
    logits = jnp.log(jnp.maximum(w, 1e-30))
    return jax.random.categorical(key, logits, shape=(m,)).astype(jnp.int32)


def inclusion_counts(idx: jnp.ndarray, n: int) -> jnp.ndarray:
    """How many times each source example was selected (diagnostics +
    the minimal-variance property test)."""
    return jnp.zeros((n,), jnp.int32).at[idx].add(1)
