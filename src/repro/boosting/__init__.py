"""Sparrow — TMSN applied to boosted decision stumps (paper §3-4) —
plus the baselines the paper compares against (XGBoost-like exact
greedy histograms, LightGBM-like GOSS) and a synchronous AdaBoost
reference."""

from repro.boosting.stumps import (
    StumpModel,
    empty_model,
    append_stump,
    predict_margin,
    predict_margin_delta,
    edge_histogram,
    edges_from_histogram,
    exp_loss,
    model_payload_bytes,
)
from repro.boosting.scanner import ScannerConfig, ScannerState, init_scanner, scan_chunk
from repro.boosting.sampler import minimal_variance_sample, rejection_sample
from repro.boosting.sparrow import (
    SparrowConfig,
    SparrowWorker,
    SparrowState,
    draw_sample,
    feature_ownership_masks,
)
from repro.boosting.batched_sparrow import BatchedSparrowState, BatchedSparrowWorker
from repro.boosting.baselines import (
    BoosterConfig,
    train_exact_greedy,
    train_goss,
    train_adaboost_reference,
    BoostTrace,
)

__all__ = [
    "StumpModel",
    "empty_model",
    "append_stump",
    "predict_margin",
    "predict_margin_delta",
    "edge_histogram",
    "edges_from_histogram",
    "exp_loss",
    "model_payload_bytes",
    "ScannerConfig",
    "ScannerState",
    "init_scanner",
    "scan_chunk",
    "minimal_variance_sample",
    "rejection_sample",
    "SparrowConfig",
    "SparrowWorker",
    "SparrowState",
    "BatchedSparrowWorker",
    "BatchedSparrowState",
    "draw_sample",
    "feature_ownership_masks",
    "BoosterConfig",
    "train_exact_greedy",
    "train_goss",
    "train_adaboost_reference",
    "BoostTrace",
]
