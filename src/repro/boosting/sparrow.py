"""A Sparrow worker (paper §4) pluggable into the TMSN simulator.

Each worker owns a subset of the features (feature-based
parallelization), keeps the full "disk" dataset as a shared read-only
reference, maintains an in-memory weighted sample of size ``m``, and
alternates between Scanning and Sampling (as in the paper's current
implementation — footnote 3).

Certificates: the log-potential bound ``L_t = sum_k 1/2 log(1 - 4 g_k^2)``
over the certified edges ``g_k`` of the stumps in the strong rule. The
stopping rule guarantees each certified edge holds w.h.p., which makes
``exp(L_t)`` a sound high-probability upper bound on the true potential
``Z(H_t)`` — exactly the "certificate of quality" of §4.2.

Cost model (simulated seconds = cost units / worker speed):
    cost = examples_touched + STUMP_EVAL_COST * incremental_stump_evals
A fresh sampling pass touches all n disk examples and pays incremental
weight refresh on them too (the paper: "run time is now dominated by the
time it takes to create new samples").
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.boosting.sampler import minimal_variance_sample
from repro.boosting.scanner import (
    SampleState,
    ScannerConfig,
    ScannerState,
    init_scanner,
    reset_after_fire,
    reset_after_fruitless_pass,
    scan_chunk,
)
from repro.boosting.stumps import (
    StumpModel,
    alpha_from_gamma,
    append_stump,
    empty_model,
    model_payload_bytes,
    predict_margin_delta,
)
from repro.core.ess import effective_sample_size

STUMP_EVAL_COST = 0.1  # relative cost of one incremental stump eval vs one example read


def feature_ownership_masks(d: int, n_workers: int, redundancy: int = 1) -> np.ndarray:
    """(n_workers, d) bool ownership masks (feature-based parallelization,
    §4): feature j belongs to workers {j mod k, ..., j mod k + r - 1}."""
    k = n_workers
    r = max(1, min(redundancy, k))
    fmod = np.arange(d) % k
    masks = np.zeros((k, d), bool)
    for wid in range(k):
        for j in range(r):
            masks[wid] |= fmod == ((wid + j) % k)
    return masks


def draw_sample(
    key: jax.Array,
    disk_xb: jnp.ndarray,
    disk_y: jnp.ndarray,
    model: StumpModel,
    disk_margin: jnp.ndarray,
    sample_size: int,
) -> SampleState:
    """Draw a fresh in-memory sample from the disk set (pure jnp, so the
    batched worker can ``vmap`` it over stacked per-worker states)."""
    w = jnp.exp(jnp.clip(-disk_y * disk_margin, -30.0, 30.0))
    idx = minimal_variance_sample(key, w, sample_size)
    margin = disk_margin[idx]
    return SampleState(
        xb=disk_xb[idx],
        y=disk_y[idx],
        margin_s=margin,
        margin_l=margin,
        t_l=jnp.full((sample_size,), model.count, jnp.int32),
    )


@dataclasses.dataclass(frozen=True)
class SparrowConfig:
    sample_size: int = 8192  # m — in-memory sample size
    capacity: int = 256  # strong-rule capacity T_max
    scanner: ScannerConfig = ScannerConfig()
    ess_threshold: float = 0.1  # resample when n_eff/m drops below this
    keep_gamma_on_fire: bool = True  # False = paper pseudocode (reset to gamma0)
    n_workers: int = 1  # for feature ownership
    use_kernel: bool = False  # route the chunk scan through the Pallas kernel
    #: beyond-paper: each feature owned by r workers (r>1 keeps the full
    #: hypothesis space reachable when workers fail-stop; EXPERIMENTS.md
    #: §Repro shows r=1 loses certificate progress under failures)
    ownership_redundancy: int = 1
    #: memory-hierarchy pricing (paper §1/§5: scanning the in-memory
    #: sample is much cheaper than streaming the full set from disk).
    #: Scan-chunk cost is scaled by mem_read_cost; the Sampler's full
    #: pass is charged disk_read_cost per example.
    mem_read_cost: float = 1.0
    disk_read_cost: float = 1.0
    #: beyond-paper (the paper's own footnote 3 + conclusion "run time is
    #: now dominated by ... creating new samples"): run the Sampler on a
    #: second core, overlapped with scanning. The scanner only blocks for
    #: the part of the disk pass not already covered by scan time since
    #: the previous resample.
    parallel_sampler: bool = False


class SparrowState(NamedTuple):
    worker_id: int
    model: StumpModel
    cert: float  # log-potential bound (lower = better)
    scanner: ScannerState
    sample: SampleState
    # disk-side lazy weight bookkeeping
    disk_margin: jnp.ndarray  # (n,)
    disk_t: jnp.ndarray  # (n,) i32
    key: jax.Array
    needs_resample: bool
    pending_cost: float  # cost incurred by adopt (charged on next segment)
    fires: int
    resamples: int
    sample_model_count: int  # stump count when the current sample was drawn
    scan_since_resample: float = 0.0  # for the parallel-sampler overlap model


class SparrowWorkerBase:
    """Shared disk-set/config initialization for the unbatched and
    batched Sparrow workers: dtype coercion, sample-size validation,
    and the feature-ownership table live in ONE place so the two
    workers (whose equivalence tests pin segment-for-segment) cannot
    silently diverge on setup."""

    def __init__(
        self,
        disk_xb: jnp.ndarray,
        disk_y: jnp.ndarray,
        config: SparrowConfig,
    ) -> None:
        self.xb = jnp.asarray(disk_xb, jnp.int32)
        self.y = jnp.asarray(disk_y, jnp.float32)
        self.n, self.d = self.xb.shape
        self.config = config
        if config.sample_size > self.n:
            raise ValueError("sample_size exceeds dataset size")
        # ownership is static per run; feature_mask sits on the
        # per-segment hot path, so build the table once
        self._feat_masks = jnp.asarray(
            feature_ownership_masks(self.d, config.n_workers, config.ownership_redundancy)
        )

    # ----- feature ownership (feature-based parallelization, §4) -----
    def feature_mask(self, worker_id: int) -> jnp.ndarray:
        return self._feat_masks[worker_id]


class SparrowWorker(SparrowWorkerBase):
    """Implements the simulator's TMSNWorker protocol for Sparrow."""

    # ----- protocol hooks -----
    def init_state(self, worker_id: int, seed: int) -> SparrowState:
        key = jax.random.PRNGKey(seed)
        model = empty_model(self.config.capacity)
        disk_margin = jnp.zeros((self.n,), jnp.float32)
        disk_t = jnp.zeros((self.n,), jnp.int32)
        key, sub = jax.random.split(key)
        sample = self._draw_sample(sub, model, disk_margin)
        return SparrowState(
            worker_id=worker_id,
            model=model,
            cert=0.0,  # log Z(H_0) = log 1
            scanner=init_scanner(self.d, self.config.scanner),
            sample=sample,
            disk_margin=disk_margin,
            disk_t=disk_t,
            key=key,
            needs_resample=False,
            pending_cost=0.0,
            fires=0,
            resamples=0,
            sample_model_count=0,
        )

    def _draw_sample(
        self, key: jax.Array, model: StumpModel, disk_margin: jnp.ndarray
    ) -> SampleState:
        return draw_sample(key, self.xb, self.y, model, disk_margin, self.config.sample_size)

    def run_segment(self, state: SparrowState) -> tuple[SparrowState, float, bool]:
        cost = state.pending_cost
        state = state._replace(pending_cost=0.0)
        if state.needs_resample:
            state, c = self._resample(state)
            return state, cost + c, False
        state, c, fired = self._scan_one_chunk(state)
        return state, cost + c, fired

    def _resample(self, state: SparrowState) -> tuple[SparrowState, float]:
        # Refresh disk weights incrementally (Sampler shares the
        # incremental-update bookkeeping with the Scanner).
        delta = predict_margin_delta(state.model, self.xb, state.disk_t)
        disk_margin = state.disk_margin + delta
        evals = float(jnp.sum(jnp.minimum(state.model.count - state.disk_t, state.model.capacity)))
        disk_t = jnp.full_like(state.disk_t, state.model.count)
        key, sub = jax.random.split(state.key)
        sample = self._draw_sample(sub, state.model, disk_margin)
        cost = self.n * self.config.disk_read_cost + STUMP_EVAL_COST * evals
        if self.config.parallel_sampler:
            # the sampler ran on a second core overlapped with scanning;
            # only the uncovered remainder blocks the scanner
            cost = max(cost - state.scan_since_resample, 0.0)
        new_state = state._replace(
            sample=sample,
            disk_margin=disk_margin,
            disk_t=disk_t,
            key=key,
            needs_resample=False,
            scanner=reset_after_fire(state.scanner, True, self.config.scanner)._replace(
                pos=jnp.zeros((), jnp.int32)
            ),
            resamples=state.resamples + 1,
            sample_model_count=int(state.model.count),
            scan_since_resample=0.0,
        )
        return new_state, cost

    def _scan_one_chunk(self, state: SparrowState) -> tuple[SparrowState, float, bool]:
        cfg = self.config
        scanner, sample, info = scan_chunk(
            state.scanner, state.sample, state.model, self.feature_mask(state.worker_id), cfg.scanner
        )
        chunk = min(cfg.scanner.chunk_size, cfg.sample_size)
        cost = chunk * cfg.mem_read_cost + STUMP_EVAL_COST * float(info.stump_evals)
        fired = bool(info.fired)
        state = state._replace(
            scanner=scanner, sample=sample,
            scan_since_resample=state.scan_since_resample + cost,
        )
        if fired:
            # alpha + certificate from the sound lower confidence bound
            # on the edge (>= the tested gamma; see scanner.scan_chunk)
            gamma = jnp.asarray(info.cert_gamma)
            alpha = alpha_from_gamma(gamma)
            model = append_stump(state.model, info.feat, info.thr, info.sign, alpha)
            if int(model.count) == int(state.model.count):
                # at capacity: the strong rule cannot grow — do NOT
                # advance the certificate (it would claim progress the
                # model does not contain)
                return state, cost, False
            cert = state.cert + 0.5 * float(jnp.log1p(-4.0 * float(gamma) ** 2))
            scanner = reset_after_fire(
                scanner, cfg.keep_gamma_on_fire, cfg.scanner, info.emp_gamma
            )
            state = state._replace(
                model=model, cert=cert, scanner=scanner, fires=state.fires + 1
            )
            # ESS check (prose of §3): stale sample -> schedule resample.
            w = jnp.exp(
                jnp.clip(-state.sample.y * (state.sample.margin_l - state.sample.margin_s), -30.0, 30.0)
            )
            ess = float(effective_sample_size(w))
            if ess / cfg.sample_size < cfg.ess_threshold:
                state = state._replace(needs_resample=True)
        elif bool(info.full_pass):
            # Full cycle without firing: halve gamma, clear accumulators
            # (no example double-counted within one "invocation") and
            # KEEP SCANNING. Resampling is driven by the ESS test alone
            # (paper §3); a fruitless pass only means the target edge was
            # too ambitious. Last resort: if gamma has hit the floor and
            # the model has advanced since sampling, draw a fresh sample.
            scanner2 = reset_after_fruitless_pass(state.scanner)
            advanced = int(state.model.count) > state.sample_model_count
            exhausted = float(state.scanner.gamma) <= 2e-4 and advanced
            w = jnp.exp(
                jnp.clip(-state.sample.y * (state.sample.margin_l - state.sample.margin_s), -30.0, 30.0)
            )
            ess = float(effective_sample_size(w))
            stale = ess / self.config.sample_size < self.config.ess_threshold
            state = state._replace(scanner=scanner2, needs_resample=stale or exhausted)
        return state, cost, fired

    def certificate(self, state: SparrowState) -> float:
        return state.cert

    def export_model(self, state: SparrowState) -> StumpModel:
        return state.model

    def payload_bytes(self, model: StumpModel) -> int:
        return model_payload_bytes(model)

    @staticmethod
    def _common_prefix(a: StumpModel, b: StumpModel) -> int:
        """Length of the shared stump prefix (adopted models usually
        extend a common broadcast lineage, so this is long)."""
        n = min(int(a.count), int(b.count))
        if n == 0:
            return 0
        same = (
            (np.asarray(a.feat[:n]) == np.asarray(b.feat[:n]))
            & (np.asarray(a.thr[:n]) == np.asarray(b.thr[:n]))
            & (np.asarray(a.sign[:n]) == np.asarray(b.sign[:n]))
            & (np.asarray(a.alpha[:n]) == np.asarray(b.alpha[:n]))
        )
        bad = np.flatnonzero(~same)
        return int(bad[0]) if bad.size else n

    def adopt(self, state: SparrowState, model: StumpModel, certificate: float) -> SparrowState:
        """Interrupt + replace (H, L).

        Incremental margin transfer (paper §4.1 applied across models):
        adopted models share a long common prefix ``p`` with the local
        lineage, so only the two divergent suffixes are re-evaluated:

            margin_new = margin_old_full - delta_old(p..oc) + delta_new(p..nc)

        Cost is m x (suffix lengths) stump-evals — NOT m x count (a full
        recompute per adoption made 10-worker runs ~10x slower; §Repro).
        """
        oc, nc = int(state.model.count), int(model.count)
        p = self._common_prefix(state.model, model)
        xb = state.sample.xb
        # 1. bring margins current under the OLD model (lazy work due anyway)
        catchup = predict_margin_delta(state.model, xb, state.sample.t_l)
        evals = float(jnp.sum(jnp.clip(state.model.count - state.sample.t_l, 0, None)))
        full_old = state.sample.margin_l + catchup
        # 2. swap the divergent suffixes
        pfx = jnp.full((xb.shape[0],), p, jnp.int32)
        old_sfx = predict_margin_delta(state.model, xb, pfx)
        new_sfx = predict_margin_delta(model, xb, pfx)
        m_new = full_old - old_sfx + new_sfx
        evals += float(xb.shape[0] * ((oc - p) + (nc - p)))
        sample = state.sample._replace(
            # keep margin_s so scan weights stay importance-corrected
            margin_l=m_new,
            t_l=jnp.full_like(state.sample.t_l, model.count),
        )
        # disk bookkeeping: valid iff the divergence is beyond the last
        # disk refresh (disk_t is uniform per resample)
        disk_t0 = int(state.disk_t[0])
        if p >= disk_t0:
            disk_margin, disk_t = state.disk_margin, state.disk_t
        else:
            disk_margin = jnp.zeros_like(state.disk_margin)
            disk_t = jnp.zeros_like(state.disk_t)
        recompute_cost = STUMP_EVAL_COST * evals * self.config.mem_read_cost
        return state._replace(
            model=model,
            cert=float(certificate),
            sample=sample,
            disk_margin=disk_margin,
            disk_t=disk_t,
            scanner=reset_after_fire(state.scanner, True, self.config.scanner),
            pending_cost=state.pending_cost + recompute_cost,
        )
