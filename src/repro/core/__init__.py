"""TMSN core: certificates, stopping rules, protocol, async simulator.

The paper's first contribution is the *protocol*: independent workers,
each holding a (model, certificate) pair, broadcasting only when the
certificate improves by more than a gap ``eps`` and accepting incoming
pairs only when they beat the local certificate by ``eps``.
"""

from repro.core.ess import effective_sample_size
from repro.core.stopping import (
    StoppingRuleParams,
    stopping_rule_fires,
    stopping_threshold,
)
from repro.core.protocol import Certificate, TMSNMessage, accepts, improves
from repro.core.result import SimResult, TrafficCounters
from repro.core.simulator import (
    SimulatorConfig,
    WorkerSpec,
    TMSNSimulator,
)
from repro.core.worker import (
    BatchedTMSNWorker,
    TMSNWorker,
    export_payload_rows,
    has_resample_hooks,
    payload_bytes_from_export,
    resolve_payload_bytes,
)
from repro.core.engine import (
    EngineConfig,
    TMSNEngine,
    make_engine,
    quantize_latency,
)
from repro.core.engine_sharded import ShardedTMSNEngine, sharded_engine_available

__all__ = [
    "effective_sample_size",
    "StoppingRuleParams",
    "stopping_rule_fires",
    "stopping_threshold",
    "Certificate",
    "TMSNMessage",
    "accepts",
    "improves",
    "SimulatorConfig",
    "WorkerSpec",
    "TMSNSimulator",
    "SimResult",
    "TrafficCounters",
    "TMSNWorker",
    "BatchedTMSNWorker",
    "export_payload_rows",
    "has_resample_hooks",
    "payload_bytes_from_export",
    "resolve_payload_bytes",
    "EngineConfig",
    "TMSNEngine",
    "ShardedTMSNEngine",
    "make_engine",
    "quantize_latency",
    "sharded_engine_available",
]
