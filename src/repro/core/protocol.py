"""The TMSN protocol proper (paper §2, §4.2).

A *certificate* is a sound high-probability bound on the quality of a
model: for Sparrow it is the performance score ``z`` (an upper bound on
the loss potential Z of the strong rule); for TMSN-SGD it is a loss EMA
plus a concentration width. TMSN's correctness needs only soundness of
certificates; its speed needs tightness.

Protocol rules (eps = the "gap"):

  * ``improves(old, new, eps)`` — a worker broadcasts iff its own new
    certificate beats its previous one by more than eps.
  * ``accepts(local, incoming, eps)`` — a worker adopts an incoming pair
    iff the incoming certificate beats the local one by more than eps;
    otherwise the message is discarded.

Both are pure and jit-safe so the SPMD mapping can reuse them.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Generic, TypeVar

import jax.numpy as jnp

ModelT = TypeVar("ModelT")


@dataclasses.dataclass(frozen=True)
class Certificate:
    """A sound upper bound on the loss of a model.

    ``value`` is the bound itself (lower is better). ``confidence`` is
    1 - sigma for bookkeeping/diagnostics only — the protocol never
    branches on it.
    """

    value: float
    confidence: float = 1.0

    def __post_init__(self) -> None:
        if not (0.0 <= self.confidence <= 1.0):
            raise ValueError(f"confidence must be in [0,1], got {self.confidence}")


@dataclasses.dataclass(frozen=True)
class TMSNMessage(Generic[ModelT]):
    """The broadcast payload ``(H, L)``: a model and its certificate."""

    model: ModelT
    certificate: Certificate
    sender: int
    seq: int = 0  # sender-local sequence number, for tracing only
    payload_bytes: int = 0  # for the communication-cost accounting


def improves(old: float | jnp.ndarray, new: float | jnp.ndarray, eps: float) -> Any:
    """Does ``new`` improve on ``old`` by more than the gap? (broadcast test)"""
    return new < old - eps


def accepts(local: float | jnp.ndarray, incoming: float | jnp.ndarray, eps: float) -> Any:
    """Does an incoming certificate beat the local one by more than the
    gap? (accept/reject test — paper §4.2: accept iff ``z_t < z``)."""
    return incoming < local - eps
