"""Device-sharded TMSN engine (fidelity level 3).

:class:`~repro.core.engine.TMSNEngine` advances all W workers on one
device; faithful to the round semantics, but the paper's deployment is
*independent machines* that only exchange "something new" over
broadcast. This engine makes that physical: the stacked ``(W, ...)``
worker state is partitioned over a ``workers`` mesh axis with
``shard_map``, each device advances only its ``W_local = W / n_dev``
worker shard per round, and gossip is one explicit collective.

What changes relative to the single-device engine:

  * the ``(W, W, D)`` in-flight certificate buffer becomes a per-shard
    ``(W_local, W, D)`` slice — destination-sharded, source-global —
    so delivery (an argmin over sources) stays a local operation;
  * broadcast is an ``all_gather`` of the round's certificates, fired
    flags, and model payloads: O(W · payload) bytes per round on the
    interconnect (reported as ``SimResult.gossip_bytes_per_round``),
    instead of materializing every worker's full training state
    everywhere;
  * the ``(D, W)`` model-snapshot ring is *replicated* per shard but
    fed only by the gathered payloads, so any destination can look up
    any source's delayed snapshot without a second exchange;
  * traffic counters are per-shard partials of shape ``(n_dev,)``
    (summing inside the step would cost a ``psum`` per round);
    :meth:`~repro.core.result.TrafficCounters.from_shards` reduces
    them once at the end of the run.

Equivalence contract: the per-worker math is elementwise over the
worker axis and delivery argmins run over the full source axis in both
engines, so on identical configs and seeds the sharded engine produces
final certificates *identical* to the single-device engine — including
fail-stop masks and laggard compute credit. ``tests/test_sharded_engine.py``
pins this on 8 forced host devices.

Worker contract addition: inside the shard-mapped step the
:class:`~repro.core.engine.BatchedTMSNWorker` methods see *local*
shards (leading axis ``W_local``, not ``W``). Workers must therefore
carry every per-worker constant (feature-ownership masks, worker ids
embedded in payloads, ...) in the state pytree — sharded along with it
— and never synthesize global worker identity from a leaf's leading
dimension. Shared read-only references (the disk dataset) are closed
over and replicated to every device, matching the paper's shared-disk
model.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine import (
    BatchedTMSNWorker,
    EngineConfig,
    EngineState,
    RoundInfo,
    TMSNEngine,
)
from repro.core.protocol import accepts, improves


class _ShardConsts(NamedTuple):
    """Static per-worker vectors, passed as sharded step arguments (a
    closure capture would replicate them; these must arrive pre-sliced
    per shard)."""

    speed: jnp.ndarray  # (W,) -> (W_local,) per shard
    speed_norm: jnp.ndarray  # (W,) -> (W_local,)
    fail_round: jnp.ndarray  # (W,) -> (W_local,)
    delay_t: jnp.ndarray  # (W, W) [dst, src] -> (W_local, W)


class ShardedTMSNEngine(TMSNEngine):
    """Round-based TMSN run sharded over a ``workers`` mesh axis."""

    def __init__(self, worker: BatchedTMSNWorker, config: EngineConfig) -> None:
        mesh = config.mesh
        if mesh is None:
            raise ValueError("ShardedTMSNEngine needs EngineConfig.mesh")
        if tuple(mesh.axis_names) != ("workers",):
            raise ValueError(
                f"engine mesh must have exactly the 'workers' axis, got {mesh.axis_names}"
            )
        self._n_dev = mesh.shape["workers"]
        if config.n_workers % self._n_dev:
            raise ValueError(
                f"n_workers={config.n_workers} must divide over {self._n_dev} devices"
            )
        self._w_local = config.n_workers // self._n_dev
        super().__init__(worker, config)

    # ------------------------------------------------------------------
    def _build_step(self):
        mesh = self.config.mesh
        state_specs = EngineState(
            worker=P("workers"),
            alive=P("workers"),
            credit=P("workers"),
            clock=P("workers"),
            inflight=P("workers"),
            ring=P(),  # replicated; every shard applies the same gathered update
            round=P(),
            sent=P("workers"),
            accepted=P("workers"),
            discarded=P("workers"),
            cost_total=P("workers"),
        )
        info_specs = RoundInfo(
            certs=P("workers"), changed=P("workers"), clock=P("workers"), alive=P("workers")
        )
        consts_specs = _ShardConsts(
            speed=P("workers"),
            speed_norm=P("workers"),
            fail_round=P("workers"),
            delay_t=P("workers"),
        )
        step = jax.jit(
            shard_map(
                self._sharded_round_step,
                mesh=mesh,
                in_specs=(state_specs, consts_specs),
                out_specs=(state_specs, info_specs),
                check_rep=False,
            )
        )
        consts = _ShardConsts(
            speed=self._speed,
            speed_norm=self._speed_norm,
            fail_round=self._fail_round,
            # delay is stored [src, dst]; the step indexes [local dst, src]
            delay_t=jnp.transpose(self._delay),
        )
        return lambda state: step(state, consts)

    def _init_state(self) -> EngineState:
        state = super()._init_state()
        zi = jnp.zeros((self._n_dev,), jnp.int32)
        return state._replace(
            sent=zi,
            accepted=zi,
            discarded=zi,
            cost_total=jnp.zeros((self._n_dev,), jnp.float32),
        )

    def _gossip_bytes_per_round(self) -> int:
        # one all_gather per round: model payload + f32 certificate +
        # bool fired flag from every worker, landing on every shard
        return self.config.n_workers * (self.worker.payload_bytes() + 4 + 1)

    # ------------------------------------------------------------------
    def _sharded_round_step(
        self, state: EngineState, consts: _ShardConsts
    ) -> tuple[EngineState, RoundInfo]:
        cfg = self.config
        w, depth, wl = cfg.n_workers, self._depth, self._w_local
        r = state.round
        row_idx = jnp.arange(wl)
        local_ids = jax.lax.axis_index("workers") * wl + row_idx  # global dst ids
        alive = state.alive & (r < consts.fail_round)

        certs0 = self.worker.certificates(state.worker)  # (wl,)

        # --- 1. deliver arrivals due this round (all-local: the buffer
        # is destination-sharded with a global source axis) -----------------
        arr = state.inflight[:, :, 0]  # (wl dst, W src) certs
        arr_live = jnp.where(alive[:, None], arr, jnp.inf)
        best_src = jnp.argmin(arr_live, axis=1)  # (wl,) global src ids
        best_cert = arr_live[row_idx, best_src]
        take = accepts(certs0, best_cert, cfg.eps) & jnp.isfinite(best_cert)
        n_arrivals = jnp.sum(jnp.isfinite(arr), dtype=jnp.int32)
        n_taken = jnp.sum(take, dtype=jnp.int32)

        sent_slot = (r - consts.delay_t[row_idx, best_src]) % depth
        in_models = jax.tree_util.tree_map(
            lambda a: a[sent_slot, best_src], state.ring
        )

        def _adopt(operand):
            wstate, models, c, t = operand
            return self.worker.adopt_batch(wstate, models, c, t)

        # per-shard cond: a shard with no taker skips the adopt math
        wstate, adopt_cost = jax.lax.cond(
            jnp.any(take),
            _adopt,
            lambda operand: (operand[0], jnp.zeros((wl,), jnp.float32)),
            (state.worker, in_models, best_cert, take),
        )

        # --- 2. shift the in-flight buffer --------------------------------
        inflight = jnp.concatenate(
            [state.inflight[:, :, 1:], jnp.full((wl, w, 1), jnp.inf, jnp.float32)], axis=2
        )

        # --- 3. one segment per live, credit-covered local worker ---------
        credit = state.credit + consts.speed_norm
        active = alive & (credit >= 1.0 - 1e-6)
        credit = jnp.where(active, credit - 1.0, credit)

        need = self.worker.needs_resample(wstate) & active
        wstate, resample_cost = jax.lax.cond(
            jnp.any(need),
            lambda op: self.worker.resample_round(op[0], op[1]),
            lambda op: (op[0], jnp.zeros((wl,), jnp.float32)),
            (wstate, need),
        )
        scan_mask = active & ~need
        certs_pre = self.worker.certificates(wstate)
        wstate, scan_cost, fired = self.worker.scan_round(wstate, scan_mask)
        certs = self.worker.certificates(wstate)

        cost = adopt_cost + resample_cost + scan_cost
        clock = state.clock + cost / jnp.maximum(consts.speed, 1e-12)

        # --- 4+5. gossip: ONE all_gather of this round's certificates,
        # fired flags, and model payloads; feeds both the in-flight push
        # and the replicated snapshot ring ---------------------------------
        improved = fired & improves(certs_pre, certs, 0.0) & scan_mask
        gathered = jax.lax.all_gather(
            {
                "certs": certs,
                "improved": improved,
                "models": self.worker.export_models(wstate),
            },
            "workers",
            axis=0,
            tiled=True,
        )
        certs_all, improved_all = gathered["certs"], gathered["improved"]  # (W,)

        d_idx = jnp.arange(depth)[None, None, :]
        # push_mask[local dst, global src, d]
        push_mask = (
            improved_all[None, :, None]
            & alive[:, None, None]
            & (local_ids[:, None] != jnp.arange(w)[None, :])[:, :, None]
            & (d_idx == (consts.delay_t[:, :, None] - 1))
        )
        inflight = jnp.where(push_mask, certs_all[None, :, None], inflight)
        n_pushed = jnp.sum(push_mask, dtype=jnp.int32)

        ring = jax.tree_util.tree_map(
            lambda buf, m: buf.at[r % depth].set(m), state.ring, gathered["models"]
        )

        new_state = EngineState(
            worker=wstate,
            alive=alive,
            credit=credit,
            clock=clock,
            inflight=inflight,
            ring=ring,
            round=r + 1,
            # (1,)-shaped per-shard partials; (n_dev,) globally
            sent=state.sent + n_pushed,
            accepted=state.accepted + n_taken,
            discarded=state.discarded + (n_arrivals - n_taken),
            cost_total=state.cost_total + jnp.sum(cost),
        )
        info = RoundInfo(
            certs=certs, changed=take | improved, clock=clock, alive=alive
        )
        return new_state, info


def sharded_engine_available(min_devices: int = 2) -> bool:
    """True when the current backend exposes enough devices to shard
    over (CI forces 8 host devices via ``XLA_FLAGS``); the sharded test
    modules key their skip conditions on this."""
    return len(jax.devices()) >= min_devices


__all__ = ["ShardedTMSNEngine", "sharded_engine_available"]
