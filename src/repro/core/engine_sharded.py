"""Device-sharded TMSN engine (fidelity level 3).

:class:`~repro.core.engine.TMSNEngine` advances all W workers on one
device; faithful to the round semantics, but the paper's deployment is
*independent machines* that only exchange "something new" over
broadcast. This engine makes that physical: the stacked ``(W, ...)``
worker state is partitioned over a ``workers`` mesh axis with
``shard_map``, each device advances only its ``W_local = W / n_dev``
worker shard per round, and gossip is one explicit collective.

What changes relative to the single-device engine:

  * the ``(W, W, D)`` in-flight certificate buffer becomes a per-shard
    ``(W_local, W, D)`` slice — destination-sharded, source-global —
    so delivery (an argmin over sources) stays a local operation;
  * broadcast is an ``all_gather`` of the round's certificates, fired
    flags, and model payloads: O(W · payload) bytes per round on the
    interconnect (reported as ``SimResult.gossip_bytes_per_round``),
    instead of materializing every worker's full training state
    everywhere;
  * **gated gossip** (``EngineConfig.gossip_mode="gated"``) applies the
    paper's improvement gate to the interconnect itself: certificates
    and broadcast flags still all_gather densely (W·5 bytes — the
    cheap control plane), but model payloads move only for each
    device's top-``gossip_top_k`` locally-improved candidates, so the
    payload all_gather shrinks from O(W·payload) to O(n_dev·k·payload)
    and receivers resolve the global argmin among the gathered
    candidates through the existing in-flight/adopt machinery. Note
    eps still gates ACCEPTANCE only; the strict-improvement gate is
    what now also shapes traffic. Under uniform delay the adopted
    model is identical to dense mode — the per-round delivery argmin
    (lowest worker id on ties, both modes) is always its shard's
    minimum and therefore among the gathered candidates
    (``tests/test_sharded_engine.py`` pins this, including fail-stop,
    laggard credit, and the Pallas scan path). The argument leans on
    the worker-contract precondition that certificates are monotone
    non-increasing: the one receiver whose dense-mode best arrival is
    NOT the global minimum is the global-minimum worker itself
    (``push_mask`` excludes self), and monotonicity guarantees the
    same-shard runner-up that gating suppressed could never have been
    accepted by it anyway. Under heterogeneous
    delay matrices generations mix in the arrival slot and gated mode
    is an explicit, *measured* approximation (``bench_scaling.py``
    reports both modes);
  * **hierarchical pod mesh** (a 2-D ``("pod", "workers")`` mesh from
    ``launch/mesh.py::make_worker_mesh(pods=...)``): the interconnect
    itself becomes two-tier. Intra-pod gossip stays the per-round
    all_gather — but over the ``workers`` axis of ONE pod (ICI-class
    links). Cross-pod exchange is a SECOND in-flight tier: improvements
    accumulate in a per-worker pending mask (``EngineState.xpend``) and
    every ``EngineConfig.cross_pod_every_k`` rounds each device ships
    its top-``cross_pod_top_k`` pending candidates — freshest
    certificate, global worker id, model payload: the same top-k gated
    payload path — over the ``pod`` axis (DCN-class links). Receivers
    push the certificates into the in-flight buffer for cross-pod
    destinations only (same-pod destinations already heard tier 1) and
    scatter the payloads into their pod's ring replica. At
    ``cross_pod_every_k=1`` under uniform delay the pod engine is
    bit-identical to the flat all-device engine — the suppressed
    runner-up argument above applies per device, and a pending leftover
    that ships late is always dominated at every destination by a
    same-device candidate that shipped earlier (monotonicity), so it
    can neither be accepted nor displace an acceptable delivery
    (``tests/test_sharded_engine.py::TestPodMesh`` pins certs, history,
    and adoptions, dense and gated, incl. fail-stop and laggards). At
    k > 1 staleness is an explicit approximation — ``bench_scaling.py``
    reports the per-k certificate divergence and the ICI/DCN traffic
    split, never assumes them;
  * the ``(D, W)`` model-snapshot ring is *replicated* per shard but
    fed only by the gathered payloads (scattered by global worker id
    in gated mode), so any destination can look up any source's
    delayed snapshot without a second exchange. On a pod mesh the
    intra-pod gather differs between pods, so the ring is replicated
    only WITHIN a pod: the leading dim grows to ``n_pods * D`` and
    shards over the ``pod`` axis — one private ``(D, W)`` replica per
    pod, written by that pod's tier-1 gather plus the (globally
    identical) tier-2 flushes;
  * dispatch is chunked (``EngineConfig.rounds_per_dispatch``): the
    whole ``lax.scan`` over K rounds runs inside ONE ``shard_map``
    region, so per-chunk Python dispatch + host sync amortize over K
    rounds and the per-round collectives stay inside the compiled
    program. Target-crossing detection inside the scan uses a psum
    across shards;
  * **sparse in-flight state** (``EngineConfig.inflight_capacity > 0``)
    swaps the per-shard ``(W_local, W, D)`` buffer for bounded
    destination-sharded pending queues ``(W_local, C)`` fed by the same
    gathered tier-1 (and, on a pod mesh, tier-2 flush) candidates, with
    delivery + eps-gated accept + credit update fused into
    ``kernels/round_step.py`` — bit-identical to the dense buffer at
    sufficient capacity (``tests/test_sparse_inflight.py``), with every
    eviction counted in per-shard ``evicted`` / ``occ_peak`` partials;
  * **sparse control plane** (``EngineConfig.control_plane="sparse"``)
    removes the last dense-width exchange: instead of the per-round
    (W_tier,) certificate + flag all_gather (and its O(W_local·W)
    receiver-side scan/scatter), each device ships only its
    top-``gossip_top_k`` locally-improved candidates as (cert,
    global_id, round) triples — a fixed-size (n_dev, k) all_gather,
    OOB-padded — and receivers scatter them into the pending queues /
    in-flight buffer by global id: O(n_dev·k) per round, independent of
    W. Bit-identical to dense control under uniform delay — the
    suppressed-runner-up argument above applies unchanged, because the
    only receiver whose best arrival is not among the shipped top-k is
    a top-k sender itself, whose monotone local certificate already
    dominates anything suppressed (``tests/test_sparse_inflight.py``
    pins certificates, history, rounds and adoption counts across all
    substrates); a measured approximation under heterogeneous delay
    (``bench_scaling.py``, control-plane section). The
    ``kernels/round_step.py::queue_ingest`` kernel is the candidate-
    list counterpart of the fused delivery kernel;
  * traffic counters are per-shard partials of shape ``(n_dev,)``
    (summing inside the step would cost a ``psum`` per round);
    :meth:`~repro.core.result.TrafficCounters.from_shards` reduces
    them once at the end of the run — including the ICI/DCN split
    (``sent_dcn`` counts pushes that crossed a pod boundary).

Sharding contract (what lives per-shard vs replicated): per-shard —
the worker state pytree, certificates, alive/credit/clock vectors, the
destination-sharded in-flight buffer, the ``xpend`` pending mask, and
all traffic-counter partials (every ``EngineState`` field with leading
worker axis, partitioned over the whole mesh). Replicated — the round
counter, the target-crossing ``done`` flag (derived from a psum), and
on a 1-D mesh the snapshot ring; on a pod mesh the ring is replicated
per pod and sharded over the ``pod`` axis. Closed-over read-only data
(the disk dataset) is replicated to every device.

Equivalence contract: the per-worker math is elementwise over the
worker axis and delivery argmins run over the full source axis in both
engines, so on identical configs and seeds the sharded engine produces
final certificates *identical* to the single-device engine — including
fail-stop masks and laggard compute credit. ``tests/test_sharded_engine.py``
pins this on 8 forced host devices.

Serving edge: the train->serve publish hook
(:meth:`~repro.core.engine.TMSNEngine.attach_publisher` +
``EngineConfig.publish_every_k``/``publish_eps``) is inherited
unchanged — ``run()`` and :meth:`_maybe_publish` live on the base
class, publishing happens at host-side chunk boundaries, and the chunk
outputs (``state.certs``/``state.alive``/the worker pytree) are global
arrays under ``shard_map``, so exporting the best-certificate row
gathers exactly one worker's model regardless of sharding. The jitted
round step is untouched in both engines.

Worker contract addition: inside the shard-mapped step the
:class:`~repro.core.worker.BatchedTMSNWorker` methods see *local*
shards (leading axis ``W_local``, not ``W``). Workers must therefore
carry every per-worker constant (feature-ownership masks, worker ids
embedded in payloads, ...) in the state pytree — sharded along with it
— and never synthesize global worker identity from a leaf's leading
dimension. Shared read-only references (the disk dataset) are closed
over and replicated to every device, matching the paper's shared-disk
model.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine import (
    EngineConfig,
    EngineState,
    RoundInfo,
    TMSNEngine,
    _dense_push_candidates,
    _inject_faults,
    _queue_push,
    _queue_push_candidates,
)
from repro.core.protocol import accepts, improves
from repro.core.worker import BatchedTMSNWorker, export_payload_rows


class _ShardConsts(NamedTuple):
    """Static per-worker vectors, passed as sharded step arguments (a
    closure capture would replicate them; these must arrive pre-sliced
    per shard)."""

    speed: jnp.ndarray  # (W,) -> (W_local,) per shard
    speed_norm: jnp.ndarray  # (W,) -> (W_local,)
    fail_round: jnp.ndarray  # (W,) -> (W_local,)
    delay_t: jnp.ndarray  # (W, W) [dst, src] -> (W_local, W)
    join_round: jnp.ndarray  # (W,) -> (W_local,) spare-activation round


class ShardedTMSNEngine(TMSNEngine):
    """Round-based TMSN run sharded over a ``workers`` mesh axis, or
    hierarchically over a two-tier ``(pod, workers)`` mesh."""

    def __init__(self, worker: BatchedTMSNWorker, config: EngineConfig) -> None:
        mesh = config.mesh
        if mesh is None:
            raise ValueError("ShardedTMSNEngine needs EngineConfig.mesh")
        names = tuple(mesh.axis_names)
        if names == ("workers",):
            self._n_pods = 1
            self._wpp = mesh.shape["workers"]  # devices on the workers axis
        elif names == ("pod", "workers"):
            self._n_pods = mesh.shape["pod"]
            self._wpp = mesh.shape["workers"]
        else:
            raise ValueError(
                "engine mesh must have axes ('workers',) or ('pod', 'workers'), "
                f"got {names}"
            )
        #: worker-axis partition spec: over both mesh axes on a pod mesh
        self._waxes = "workers" if self._n_pods == 1 else ("pod", "workers")
        self._n_dev = self._n_pods * self._wpp
        if config.n_workers % self._n_dev:
            raise ValueError(
                f"n_workers={config.n_workers} must divide over {self._n_dev} devices"
            )
        self._w_local = config.n_workers // self._n_dev
        super().__init__(worker, config)
        if self._n_pods > 1:
            # (W,) pod of each global worker id — closure-captured by
            # the shard-mapped step (replicated; a few hundred int32s),
            # used only to realize the FaultPlan partition window
            self._pod_of = jnp.arange(config.n_workers, dtype=jnp.int32) // (
                config.n_workers // self._n_pods
            )

    # ------------------------------------------------------------------
    def _build_chunk(self, length: int):
        """Chunk dispatcher: the whole K-round ``lax.scan`` runs inside
        one ``shard_map`` region (collectives and the cross-shard
        target-crossing psum stay inside the compiled program)."""
        mesh = self.config.mesh
        wx = self._waxes
        state_specs = EngineState(
            worker=P(wx),
            certs=P(wx),
            alive=P(wx),
            credit=P(wx),
            clock=P(wx),
            inflight=P(wx),
            # single-tier: replicated (fed by the all-device gather).
            # pod mesh: the intra-pod gather differs between pods, so
            # each pod keeps its OWN ring replica — leading (n_pods*D)
            # dim sharded over the pod axis, (D, W, ...) per pod.
            ring=P() if self._n_pods == 1 else P("pod"),
            round=P(),
            sent=P(wx),
            accepted=P(wx),
            discarded=P(wx),
            cost_total=P(wx),
            xpend=P(wx),
            sent_dcn=P(wx),
            evicted=P(wx),
            occ_peak=P(wx),
            dropped_inj=P(wx),
            corrupt_rej=P(wx),
        )
        # stacked over the chunk: leading scan axis, worker axis second
        infos_specs = RoundInfo(
            certs=P(None, wx),
            changed=P(None, wx),
            clock=P(None, wx),
            alive=P(None, wx),
        )
        consts_specs = _ShardConsts(
            speed=P(wx),
            speed_norm=P(wx),
            fail_round=P(wx),
            delay_t=P(wx),
            join_round=P(wx),
        )

        def _any_shard(x):
            # scalar "any worker on any shard" — replicated across shards
            axes = ("workers",) if self._n_pods == 1 else ("pod", "workers")
            return jax.lax.psum(jnp.any(x).astype(jnp.int32), axes) > 0

        def chunk_local(state: EngineState, consts: _ShardConsts):
            body = self._chunk_body(
                lambda st: self._sharded_round_step(st, consts), _any_shard
            )
            (state, _), infos = jax.lax.scan(
                body, (state, jnp.zeros((), bool)), None, length=length
            )
            return state, infos

        step = jax.jit(
            shard_map(
                chunk_local,
                mesh=mesh,
                in_specs=(state_specs, consts_specs),
                out_specs=(state_specs, infos_specs),
                check_rep=False,
            )
        )
        consts = _ShardConsts(
            speed=self._speed,
            speed_norm=self._speed_norm,
            fail_round=self._fail_round,
            # delay is stored [src, dst]; the step indexes [local dst, src]
            delay_t=jnp.transpose(self._delay),
            join_round=self._join_round,
        )
        return lambda state: step(state, consts)

    def _init_state(self) -> EngineState:
        state = super()._init_state()
        zi = jnp.zeros((self._n_dev,), jnp.int32)
        state = state._replace(
            sent=zi,
            accepted=zi,
            discarded=zi,
            cost_total=jnp.zeros((self._n_dev,), jnp.float32),
            sent_dcn=zi,
            evicted=zi,
            occ_peak=zi,
            dropped_inj=zi,
            corrupt_rej=zi,
        )
        if self._n_pods > 1:
            # one private snapshot ring per pod (the intra-pod gather
            # feeds each pod differently): leading dim n_pods * D,
            # sharded over the pod axis to (D, W, ...) per pod. Initial
            # models are identical everywhere, so tiling is consistent.
            state = state._replace(
                ring=jax.tree_util.tree_map(
                    lambda a: jnp.broadcast_to(
                        a[None], (self._n_pods,) + a.shape
                    ).reshape((-1,) + a.shape[1:]),
                    state.ring,
                )
            )
        return state

    def _gossip_split(self) -> tuple[int, int]:
        p = self._payload_bytes
        w = self.config.n_workers
        w_tier = w // self._n_pods  # workers gathered by the intra tier
        ici_ctrl, dcn_ctrl = self._control_split()
        if self.config.gossip_mode == "gated":
            # control plane (see _control_split) + k candidate payloads
            # per device; under dense control each payload also carries
            # its int32 global worker id (under sparse control the id
            # already rides in the control triple)
            k = min(int(self.config.gossip_top_k), self._w_local)
            ici = ici_ctrl + self._wpp * k * (p + (0 if self._control_sparse else 4))
        else:
            # dense payloads: every tier worker's model, every round;
            # the certificate/flag legs are the control plane
            ici = ici_ctrl + w_tier * p
        if self._n_pods == 1:
            return ici, 0
        # cross-pod tier: top-k pending candidates per device (control
        # triple or cert+id, plus payload), gathered over ALL devices
        # every cross_pod_every_k rounds — charged to the DCN class and
        # amortized per round (the control share is inside dcn_ctrl)
        kx = min(int(self.config.cross_pod_top_k), self._w_local)
        dcn = (self._n_dev * kx * p) // int(self.config.cross_pod_every_k)
        return ici, dcn + dcn_ctrl

    def _control_split(self) -> tuple[int, int]:
        """(ICI, DCN) control-plane bytes per round — the sub-share of
        :meth:`_gossip_split` that is certificates/flags/ids rather than
        model payloads.

        Dense control: the per-round (W_tier,) all_gather of f32 certs +
        bool broadcast flags — 5 bytes per tier worker, every round.
        Sparse control: (cert, global_id, round) triples for each
        device's top-k candidates — 12 bytes per candidate, n_dev·k of
        them, independent of W. The DCN tier ships cert+id per flush
        candidate under dense control (8 B) and the full triple under
        sparse (12 B), amortized over ``cross_pod_every_k``."""
        w_tier = self.config.n_workers // self._n_pods
        if self._control_sparse:
            k = min(int(self.config.gossip_top_k), self._w_local)
            ici = self._wpp * k * 12
        else:
            ici = w_tier * 5
        if self._n_pods == 1:
            return ici, 0
        kx = min(int(self.config.cross_pod_top_k), self._w_local)
        per = 12 if self._control_sparse else 8
        return ici, (self._n_dev * kx * per) // int(self.config.cross_pod_every_k)

    def _gossip_mode(self) -> str:
        return self.config.gossip_mode

    # ------------------------------------------------------------------
    def _dev_index(self):
        """Flat device index inside the shard-mapped step, matching the
        1-D device order (``pod`` is the slow axis of the 2-D mesh)."""
        if self._n_pods == 1:
            return jax.lax.axis_index("workers")
        return jax.lax.axis_index("pod") * self._wpp + jax.lax.axis_index("workers")

    def _export_rows(self, wstate, rows: jnp.ndarray):
        """Candidate payloads for ``rows`` — the shared optional-hook
        helper from :mod:`repro.core.worker` (the worker's
        ``export_payload_rows`` when defined, else the one indexing
        fallback both candidate-selecting tiers share)."""
        return export_payload_rows(self.worker, wstate, rows)

    def _sharded_round_step(
        self, state: EngineState, consts: _ShardConsts
    ) -> tuple[EngineState, RoundInfo]:
        cfg = self.config
        w, depth, wl = cfg.n_workers, self._depth, self._w_local
        r = state.round
        row_idx = jnp.arange(wl)
        local_ids = self._dev_index() * wl + row_idx  # global dst ids
        if self._has_joins:
            # sticky joins + fail-stop, with the joiner's laggard credit
            # reseeded on its activation round (see the single-device
            # engine for the full membership notes)
            alive = (state.alive | (r >= consts.join_round)) & (r < consts.fail_round)
            credit_in = jnp.where(r == consts.join_round, 0.0, state.credit)
        else:
            alive = state.alive & (r < consts.fail_round)
            credit_in = state.credit

        # last round's post-scan certificates, carried in the state (no
        # third certificates() call per round)
        certs0 = state.certs  # (wl,)

        # --- 1. deliver arrivals due this round (all-local: both
        # representations are destination-sharded with a global source
        # axis) --------------------------------------------------------------
        if self._capacity:
            # sparse: delivery argmin + accept gate + laggard credit are
            # one fused kernel call on the (wl, C) pending queue; the
            # queue stores the ring slot, so no delay lookup is needed
            (
                inflight,
                best_cert,
                best_src,
                sent_slot,
                take,
                n_arrivals,
                credit,
                active,
            ) = self._deliver_sparse(
                state.inflight, certs0, alive, credit_in, consts.speed_norm, r
            )
        else:
            arr = state.inflight[:, :, 0]  # (wl dst, W src) certs
            arr_live = jnp.where(alive[:, None], arr, jnp.inf)
            best_src = jnp.argmin(arr_live, axis=1)  # (wl,) global src ids
            best_cert = arr_live[row_idx, best_src]
            take = accepts(certs0, best_cert, cfg.eps) & jnp.isfinite(best_cert)
            n_arrivals = jnp.sum(jnp.isfinite(arr), dtype=jnp.int32)
            sent_slot = (r - consts.delay_t[row_idx, best_src]) % depth
        n_taken = jnp.sum(take, dtype=jnp.int32)
        in_models = jax.tree_util.tree_map(
            lambda a: a[sent_slot, best_src], state.ring
        )

        def _adopt(operand):
            wstate, models, c, t = operand
            return self.worker.adopt_batch(wstate, models, c, t)

        # per-shard cond: a shard with no taker skips the adopt math
        wstate, adopt_cost = jax.lax.cond(
            jnp.any(take),
            _adopt,
            lambda operand: (operand[0], jnp.zeros((wl,), jnp.float32)),
            (state.worker, in_models, best_cert, take),
        )

        # --- 2.+3. shift the dense buffer, accrue compute credit (both
        # already folded into the fused kernel on the sparse path) ----------
        if not self._capacity:
            inflight = jnp.concatenate(
                [state.inflight[:, :, 1:], jnp.full((wl, w, 1), jnp.inf, jnp.float32)],
                axis=2,
            )
            credit = credit_in + consts.speed_norm
            active = alive & (credit >= 1.0 - 1e-6)
            credit = jnp.where(active, credit - 1.0, credit)

        # optional resample hooks: statically absent for workers
        # without a sampling phase (repro.core.worker.has_resample_hooks)
        if self._has_resample:
            need = self.worker.needs_resample(wstate) & active
            wstate, resample_cost = jax.lax.cond(
                jnp.any(need),
                lambda op: self.worker.resample_round(op[0], op[1]),
                lambda op: (op[0], jnp.zeros((wl,), jnp.float32)),
                (wstate, need),
            )
            scan_mask = active & ~need
        else:
            resample_cost = jnp.zeros((wl,), jnp.float32)
            scan_mask = active
        certs_pre = self.worker.certificates(wstate)
        wstate, scan_cost, fired = self.worker.scan_round(wstate, scan_mask)
        certs = self.worker.certificates(wstate)

        cost = adopt_cost + resample_cost + scan_cost
        clock = state.clock + cost / jnp.maximum(consts.speed, 1e-12)

        # --- 4+5. gossip, tier 1 (intra-pod / single-axis). Under the
        # DENSE control plane, certificates + broadcast flags gather
        # densely over the ``workers`` axis; model payloads gather for
        # every worker ("dense") or only for each device's top-k
        # locally-improved candidates ("gated"). Under the SPARSE
        # control plane (control_plane="sparse") there is NO (W_tier,)
        # leg at all: the exchange carries only each device's top-k
        # candidates as (cert, global_id) pairs — a fixed-size
        # (n_dev, k) gather, OOB-padded — and receivers scatter them
        # into the in-flight state by global id. On a 1-D mesh the
        # ``workers`` axis spans every device and this is the ONLY tier;
        # on a pod mesh it spans one pod, and (dense control only) the
        # gathered (W_pod,) control plane is scattered into the
        # (W,)-wide arrays at the pod's contiguous global-id block ----------
        improved = fired & improves(certs_pre, certs, 0.0) & scan_mask
        w_tier = w // self._n_pods  # workers visible to the intra tier
        pod_idx = jax.lax.axis_index("pod") if self._n_pods > 1 else None
        n_evicted = jnp.zeros((), jnp.int32)
        occ_pre_max = jnp.zeros((), jnp.int32)
        n_dropped = jnp.zeros((), jnp.int32)
        n_rejected = jnp.zeros((), jnp.int32)
        if self._control_sparse:
            kc = min(int(cfg.gossip_top_k), wl)
            cand_rows, cand_valid = self._top_k_candidates(improved, certs, kc)
            cand_ids = jnp.where(cand_valid, local_ids[cand_rows], w)
            cand_certs = jnp.where(cand_valid, certs[cand_rows], jnp.inf)
            if cfg.gossip_mode == "gated":
                # one collective: the (k,) control triples and the (k,)
                # candidate payloads ride together
                gathered = jax.lax.all_gather(
                    {
                        "certs": cand_certs,
                        "ids": cand_ids,
                        "models": self._export_rows(wstate, cand_rows),
                    },
                    "workers",
                    axis=0,
                    tiled=True,
                )  # every leg (wpp * kc, ...)
                ring = jax.tree_util.tree_map(
                    lambda buf, m: buf.at[r % depth, gathered["ids"]].set(
                        m, mode="drop"
                    ),
                    state.ring,
                    gathered["models"],
                )
            else:
                # dense payload plane, sparse control plane: every tier
                # worker's model still gathers, but only candidate rows
                # are ever referenced by the in-flight state, so only
                # those ring rows are written (scattered by global id;
                # invalid candidates point out of bounds and drop)
                gathered = jax.lax.all_gather(
                    {
                        "certs": cand_certs,
                        "ids": cand_ids,
                        "models": self.worker.export_models(wstate),
                    },
                    "workers",
                    axis=0,
                    tiled=True,
                )  # certs/ids: (wpp * kc,); models: (w_tier, ...)
                base = 0 if self._n_pods == 1 else pod_idx * w_tier
                rows_t = jnp.clip(gathered["ids"] - base, 0, w_tier - 1)
                ring = jax.tree_util.tree_map(
                    lambda buf, m: buf.at[r % depth, gathered["ids"]].set(
                        m[rows_t], mode="drop"
                    ),
                    state.ring,
                    gathered["models"],
                )
            if self._capacity:
                (
                    inflight,
                    n_pushed,
                    n_evicted,
                    occ_pre_max,
                    n_dropped,
                    n_rejected,
                ) = _queue_push_candidates(
                    inflight,
                    gathered["certs"],
                    gathered["ids"],
                    alive,
                    local_ids,
                    consts.delay_t,
                    r,
                    depth,
                    cfg.round_step_impl,
                    dst_cert=certs,
                    fault=self._fault,
                    pod_of=self._pod_of,
                )
            else:
                inflight, n_pushed, n_dropped, n_rejected = _dense_push_candidates(
                    inflight,
                    gathered["certs"],
                    gathered["ids"],
                    alive,
                    local_ids,
                    consts.delay_t,
                    r=r,
                    dst_cert=certs,
                    fault=self._fault,
                    pod_of=self._pod_of,
                )
        elif cfg.gossip_mode == "gated":
            k = min(int(cfg.gossip_top_k), wl)
            cand_rows, cand_valid = self._top_k_candidates(improved, certs, k)
            bcast = jnp.zeros((wl,), bool).at[cand_rows].set(cand_valid)
            # ONE collective: tiled gathers are per-leaf, so the (wl,)
            # control plane and the (k,) payload leg ride together —
            # at gated payload sizes the per-collective launch latency
            # is the cost that matters
            gathered = jax.lax.all_gather(
                {
                    "certs": certs,
                    "bcast": bcast,
                    # un-improved candidate slots point out of bounds so
                    # the ring scatter drops them
                    "ids": jnp.where(cand_valid, local_ids[cand_rows], w),
                    "models": self._export_rows(wstate, cand_rows),
                },
                "workers",
                axis=0,
                tiled=True,
            )  # certs/bcast: (w_tier,); ids/models: (wpp * k, ...)
            tier_certs, tier_bcast = gathered["certs"], gathered["bcast"]
            ring = jax.tree_util.tree_map(
                lambda buf, m: buf.at[r % depth, gathered["ids"]].set(m, mode="drop"),
                state.ring,
                gathered["models"],
            )
        else:
            gathered = jax.lax.all_gather(
                {
                    "certs": certs,
                    "improved": improved,
                    "models": self.worker.export_models(wstate),
                },
                "workers",
                axis=0,
                tiled=True,
            )
            tier_certs, tier_bcast = gathered["certs"], gathered["improved"]
            # ring writes gated to broadcasters (only their entries are
            # ever read back), mirroring the single-device engine
            if self._n_pods == 1:
                ring = jax.tree_util.tree_map(
                    lambda buf, m: buf.at[r % depth].set(
                        jnp.where(
                            tier_bcast.reshape((-1,) + (1,) * (m.ndim - 1)),
                            m,
                            buf[r % depth],
                        )
                    ),
                    state.ring,
                    gathered["models"],
                )

        if not self._control_sparse:
            if self._n_pods == 1:
                certs_all, bcast_all = tier_certs, tier_bcast  # (W,)
            else:
                # scatter the pod-local control plane into global width;
                # pod p owns the contiguous global-id block
                # [p * W_pod, (p + 1) * W_pod)
                pod_gids = pod_idx * w_tier + jnp.arange(w_tier)
                certs_all = (
                    jnp.full((w,), jnp.inf, jnp.float32).at[pod_gids].set(tier_certs)
                )
                bcast_all = jnp.zeros((w,), bool).at[pod_gids].set(tier_bcast)
                if cfg.gossip_mode != "gated":
                    # dense intra-pod ring writes, scattered by global id
                    # into this pod's private ring replica (silent workers
                    # point out of bounds and drop)
                    ids = jnp.where(tier_bcast, pod_gids, w)
                    ring = jax.tree_util.tree_map(
                        lambda buf, m: buf.at[r % depth, ids].set(m, mode="drop"),
                        state.ring,
                        gathered["models"],
                    )

            if self._capacity:
                # tier-1 push into the (wl, C) pending queues: the
                # gathered control plane is dense-width in both gossip
                # modes, so one (W,) candidate score serves dense and
                # gated alike; on a pod mesh bcast_all is zero outside
                # this pod
                (
                    inflight,
                    n_pushed,
                    n_evicted,
                    occ_pre_max,
                    n_dropped,
                    n_rejected,
                ) = _queue_push(
                    inflight,
                    jnp.where(bcast_all, certs_all, jnp.inf),
                    alive,
                    local_ids,
                    consts.delay_t,
                    r,
                    depth,
                    dst_cert=certs,
                    fault=self._fault,
                    pod_of=self._pod_of,
                )
            elif self._fault is None:
                d_idx = jnp.arange(depth)[None, None, :]
                # push_mask[local dst, global src, d]; on a pod mesh
                # bcast_all is zero outside this pod, so tier-1 pushes
                # stay intra-pod
                push_mask = (
                    bcast_all[None, :, None]
                    & alive[:, None, None]
                    & (local_ids[:, None] != jnp.arange(w)[None, :])[:, :, None]
                    & (d_idx == (consts.delay_t[:, :, None] - 1))
                )
                inflight = jnp.where(push_mask, certs_all[None, :, None], inflight)
                n_pushed = jnp.sum(push_mask, dtype=jnp.int32)
            else:
                # faulted dense push: per-edge (wl, W) certificate matrix
                # so _inject_faults can drop/corrupt/reject single edges
                # (mirrors the single-device engine's faulted branch)
                push2 = (
                    bcast_all[None, :]
                    & alive[:, None]
                    & (local_ids[:, None] != jnp.arange(w)[None, :])
                )
                cert_mat = jnp.where(push2, certs_all[None, :], jnp.inf)
                src_mat = jnp.broadcast_to(
                    jnp.arange(w, dtype=jnp.int32)[None, :], (wl, w)
                )
                cert_mat, _, _, n_dropped, n_rejected = _inject_faults(
                    self._fault,
                    self._pod_of,
                    r,
                    local_ids.astype(jnp.int32),
                    src_mat,
                    cert_mat,
                    None,
                    certs,
                    depth,
                )
                d_idx = jnp.arange(depth)[None, None, :]
                push_mask = jnp.isfinite(cert_mat)[:, :, None] & (
                    d_idx == (consts.delay_t[:, :, None] - 1)
                )
                inflight = jnp.where(push_mask, cert_mat[:, :, None], inflight)
                n_pushed = jnp.sum(push2, dtype=jnp.int32)  # logical sends

        # --- gossip, tier 2 (cross-pod, DCN): improvements accumulate
        # in the pending mask and the freshest certificates flush over
        # the ``pod`` axis every cross_pod_every_k rounds — the paper's
        # "tell me something new" applied to the interconnect hierarchy.
        # Each device ships its top cross_pod_top_k pending candidates
        # (the PR 3 gated payload path); receivers scatter the payloads
        # into their pod's ring replica and push the certificates into
        # the in-flight buffer for cross-pod destinations only (same-pod
        # destinations already got them from tier 1) ------------------------
        xpend = state.xpend
        n_pushed_x = jnp.zeros((), jnp.int32)
        if self._n_pods > 1:
            xpend = xpend | improved
            kx = min(int(cfg.cross_pod_top_k), wl)
            src_pod = jnp.arange(w) // w_tier  # (W,) pod of each global id

            def _flush(args):
                xpend, inflight, ring = args
                rows, valid = self._top_k_candidates(xpend, certs, kx)
                gx = jax.lax.all_gather(
                    {
                        "certs": certs[rows],
                        "ids": jnp.where(valid, local_ids[rows], w),
                        "models": self._export_rows(wstate, rows),
                    },
                    ("pod", "workers"),
                    axis=0,
                    tiled=True,
                )  # (n_dev * kx, ...), flat-device order (pod-major)
                ring = jax.tree_util.tree_map(
                    lambda buf, m: buf.at[r % depth, gx["ids"]].set(m, mode="drop"),
                    ring,
                    gx["models"],
                )
                flushed = jnp.zeros((wl,), bool).at[rows].set(valid)
                if self._control_sparse:
                    # sparse control: push the gathered flush candidates
                    # directly by global id — no (W,)-wide scatter. The
                    # cross-pod mask (same-pod destinations already
                    # heard tier 1) folds into candidate validity.
                    pod_of = jnp.clip(gx["ids"], 0, w - 1) // w_tier
                    valid_x = (gx["ids"] < w) & (pod_of != pod_idx)
                    ids_x = jnp.where(valid_x, gx["ids"], w)
                    certs_x = jnp.where(valid_x, gx["certs"], jnp.inf)
                    if self._capacity:
                        inflight, nx, ne, occ, nd, nr = _queue_push_candidates(
                            inflight,
                            certs_x,
                            ids_x,
                            alive,
                            local_ids,
                            consts.delay_t,
                            r,
                            depth,
                            cfg.round_step_impl,
                            dst_cert=certs,
                            fault=self._fault,
                            pod_of=self._pod_of,
                        )
                        return (xpend & ~flushed, inflight, ring, nx, ne, occ, nd, nr)
                    inflight, nx, nd, nr = _dense_push_candidates(
                        inflight,
                        certs_x,
                        ids_x,
                        alive,
                        local_ids,
                        consts.delay_t,
                        r=r,
                        dst_cert=certs,
                        fault=self._fault,
                        pod_of=self._pod_of,
                    )
                    z = jnp.zeros((), jnp.int32)
                    return (xpend & ~flushed, inflight, ring, nx, z, z, nd, nr)
                xcerts = (
                    jnp.full((w,), jnp.inf, jnp.float32)
                    .at[gx["ids"]]
                    .set(gx["certs"], mode="drop")
                )
                xbcast = (
                    jnp.zeros((w,), bool)
                    .at[gx["ids"]]
                    .set(jnp.ones_like(gx["ids"], bool), mode="drop")
                )
                if self._capacity:
                    # same queue push as tier 1, with the candidate score
                    # masked to cross-pod sources (same-pod destinations
                    # already heard these via tier 1)
                    inflight, nx, ne, occ, nd, nr = _queue_push(
                        inflight,
                        jnp.where(xbcast & (src_pod != pod_idx), xcerts, jnp.inf),
                        alive,
                        local_ids,
                        consts.delay_t,
                        r,
                        depth,
                        dst_cert=certs,
                        fault=self._fault,
                        pod_of=self._pod_of,
                    )
                    return (xpend & ~flushed, inflight, ring, nx, ne, occ, nd, nr)
                z = jnp.zeros((), jnp.int32)
                nd = nr = z
                xpush2 = (
                    xbcast[None, :]
                    & alive[:, None]
                    # only cross-pod destinations (self-exclusion implied)
                    & (src_pod != pod_idx)[None, :]
                )
                xcert_mat = jnp.where(xpush2, xcerts[None, :], jnp.inf)
                if self._fault is not None:
                    src_mat = jnp.broadcast_to(
                        jnp.arange(w, dtype=jnp.int32)[None, :], (wl, w)
                    )
                    xcert_mat, _, _, nd, nr = _inject_faults(
                        self._fault,
                        self._pod_of,
                        r,
                        local_ids.astype(jnp.int32),
                        src_mat,
                        xcert_mat,
                        None,
                        certs,
                        depth,
                    )
                d_idx = jnp.arange(depth)[None, None, :]
                xpush = jnp.isfinite(xcert_mat)[:, :, None] & (
                    d_idx == (consts.delay_t[:, :, None] - 1)
                )
                inflight = jnp.where(xpush, xcert_mat[:, :, None], inflight)
                return (
                    xpend & ~flushed,
                    inflight,
                    ring,
                    jnp.sum(xpush2, dtype=jnp.int32),
                    z,
                    z,
                    nd,
                    nr,
                )

            if int(cfg.cross_pod_every_k) == 1:
                xpend, inflight, ring, n_pushed_x, ne_x, occ_x, nd_x, nr_x = _flush(
                    (xpend, inflight, ring)
                )
            else:
                # `r` is replicated, so every device takes the same
                # branch and the pod-axis collective stays uniform
                (
                    xpend,
                    inflight,
                    ring,
                    n_pushed_x,
                    ne_x,
                    occ_x,
                    nd_x,
                    nr_x,
                ) = jax.lax.cond(
                    (r % int(cfg.cross_pod_every_k)) == 0,
                    _flush,
                    lambda args: (
                        args[0],
                        args[1],
                        args[2],
                        jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.int32),
                        jnp.zeros((), jnp.int32),
                    ),
                    (xpend, inflight, ring),
                )
            n_evicted = n_evicted + ne_x
            occ_pre_max = jnp.maximum(occ_pre_max, occ_x)
            n_dropped = n_dropped + nd_x
            n_rejected = n_rejected + nr_x

        new_state = EngineState(
            worker=wstate,
            certs=certs,
            alive=alive,
            credit=credit,
            clock=clock,
            inflight=inflight,
            ring=ring,
            round=r + 1,
            # (1,)-shaped per-shard partials; (n_dev,) globally
            sent=state.sent + n_pushed + n_pushed_x,
            accepted=state.accepted + n_taken,
            discarded=state.discarded + (n_arrivals - n_taken),
            cost_total=state.cost_total + jnp.sum(cost),
            xpend=xpend,
            sent_dcn=state.sent_dcn + n_pushed_x,
            evicted=state.evicted + n_evicted,
            occ_peak=jnp.maximum(state.occ_peak, occ_pre_max),
            dropped_inj=state.dropped_inj + n_dropped,
            corrupt_rej=state.corrupt_rej + n_rejected,
        )
        info = RoundInfo(
            certs=certs, changed=take | improved, clock=clock, alive=alive
        )
        return new_state, info


def sharded_engine_available(min_devices: int = 2) -> bool:
    """True when the current backend exposes enough devices to shard
    over (CI forces 8 host devices via ``XLA_FLAGS``); the sharded test
    modules key their skip conditions on this."""
    return len(jax.devices()) >= min_devices


__all__ = ["ShardedTMSNEngine", "sharded_engine_available"]
