"""Device-sharded TMSN engine (fidelity level 3).

:class:`~repro.core.engine.TMSNEngine` advances all W workers on one
device; faithful to the round semantics, but the paper's deployment is
*independent machines* that only exchange "something new" over
broadcast. This engine makes that physical: the stacked ``(W, ...)``
worker state is partitioned over a ``workers`` mesh axis with
``shard_map``, each device advances only its ``W_local = W / n_dev``
worker shard per round, and gossip is one explicit collective.

What changes relative to the single-device engine:

  * the ``(W, W, D)`` in-flight certificate buffer becomes a per-shard
    ``(W_local, W, D)`` slice — destination-sharded, source-global —
    so delivery (an argmin over sources) stays a local operation;
  * broadcast is an ``all_gather`` of the round's certificates, fired
    flags, and model payloads: O(W · payload) bytes per round on the
    interconnect (reported as ``SimResult.gossip_bytes_per_round``),
    instead of materializing every worker's full training state
    everywhere;
  * **gated gossip** (``EngineConfig.gossip_mode="gated"``) applies the
    paper's improvement gate to the interconnect itself: certificates
    and broadcast flags still all_gather densely (W·5 bytes — the
    cheap control plane), but model payloads move only for each
    device's top-``gossip_top_k`` locally-improved candidates, so the
    payload all_gather shrinks from O(W·payload) to O(n_dev·k·payload)
    and receivers resolve the global argmin among the gathered
    candidates through the existing in-flight/adopt machinery. Note
    eps still gates ACCEPTANCE only; the strict-improvement gate is
    what now also shapes traffic. Under uniform delay the adopted
    model is identical to dense mode — the per-round delivery argmin
    (lowest worker id on ties, both modes) is always its shard's
    minimum and therefore among the gathered candidates
    (``tests/test_sharded_engine.py`` pins this, including fail-stop,
    laggard credit, and the Pallas scan path). The argument leans on
    the worker-contract precondition that certificates are monotone
    non-increasing: the one receiver whose dense-mode best arrival is
    NOT the global minimum is the global-minimum worker itself
    (``push_mask`` excludes self), and monotonicity guarantees the
    same-shard runner-up that gating suppressed could never have been
    accepted by it anyway. Under heterogeneous
    delay matrices generations mix in the arrival slot and gated mode
    is an explicit, *measured* approximation (``bench_scaling.py``
    reports both modes);
  * the ``(D, W)`` model-snapshot ring is *replicated* per shard but
    fed only by the gathered payloads (scattered by global worker id
    in gated mode), so any destination can look up any source's
    delayed snapshot without a second exchange;
  * dispatch is chunked (``EngineConfig.rounds_per_dispatch``): the
    whole ``lax.scan`` over K rounds runs inside ONE ``shard_map``
    region, so per-chunk Python dispatch + host sync amortize over K
    rounds and the per-round collectives stay inside the compiled
    program. Target-crossing detection inside the scan uses a psum
    across shards;
  * traffic counters are per-shard partials of shape ``(n_dev,)``
    (summing inside the step would cost a ``psum`` per round);
    :meth:`~repro.core.result.TrafficCounters.from_shards` reduces
    them once at the end of the run.

Equivalence contract: the per-worker math is elementwise over the
worker axis and delivery argmins run over the full source axis in both
engines, so on identical configs and seeds the sharded engine produces
final certificates *identical* to the single-device engine — including
fail-stop masks and laggard compute credit. ``tests/test_sharded_engine.py``
pins this on 8 forced host devices.

Worker contract addition: inside the shard-mapped step the
:class:`~repro.core.engine.BatchedTMSNWorker` methods see *local*
shards (leading axis ``W_local``, not ``W``). Workers must therefore
carry every per-worker constant (feature-ownership masks, worker ids
embedded in payloads, ...) in the state pytree — sharded along with it
— and never synthesize global worker identity from a leaf's leading
dimension. Shared read-only references (the disk dataset) are closed
over and replicated to every device, matching the paper's shared-disk
model.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.core.engine import (
    BatchedTMSNWorker,
    EngineConfig,
    EngineState,
    RoundInfo,
    TMSNEngine,
)
from repro.core.protocol import accepts, improves


class _ShardConsts(NamedTuple):
    """Static per-worker vectors, passed as sharded step arguments (a
    closure capture would replicate them; these must arrive pre-sliced
    per shard)."""

    speed: jnp.ndarray  # (W,) -> (W_local,) per shard
    speed_norm: jnp.ndarray  # (W,) -> (W_local,)
    fail_round: jnp.ndarray  # (W,) -> (W_local,)
    delay_t: jnp.ndarray  # (W, W) [dst, src] -> (W_local, W)


class ShardedTMSNEngine(TMSNEngine):
    """Round-based TMSN run sharded over a ``workers`` mesh axis."""

    def __init__(self, worker: BatchedTMSNWorker, config: EngineConfig) -> None:
        mesh = config.mesh
        if mesh is None:
            raise ValueError("ShardedTMSNEngine needs EngineConfig.mesh")
        if tuple(mesh.axis_names) != ("workers",):
            raise ValueError(
                f"engine mesh must have exactly the 'workers' axis, got {mesh.axis_names}"
            )
        self._n_dev = mesh.shape["workers"]
        if config.n_workers % self._n_dev:
            raise ValueError(
                f"n_workers={config.n_workers} must divide over {self._n_dev} devices"
            )
        self._w_local = config.n_workers // self._n_dev
        super().__init__(worker, config)

    # ------------------------------------------------------------------
    def _build_chunk(self, length: int):
        """Chunk dispatcher: the whole K-round ``lax.scan`` runs inside
        one ``shard_map`` region (collectives and the cross-shard
        target-crossing psum stay inside the compiled program)."""
        mesh = self.config.mesh
        state_specs = EngineState(
            worker=P("workers"),
            certs=P("workers"),
            alive=P("workers"),
            credit=P("workers"),
            clock=P("workers"),
            inflight=P("workers"),
            ring=P(),  # replicated; every shard applies the same gathered update
            round=P(),
            sent=P("workers"),
            accepted=P("workers"),
            discarded=P("workers"),
            cost_total=P("workers"),
        )
        # stacked over the chunk: leading scan axis, worker axis second
        infos_specs = RoundInfo(
            certs=P(None, "workers"),
            changed=P(None, "workers"),
            clock=P(None, "workers"),
            alive=P(None, "workers"),
        )
        consts_specs = _ShardConsts(
            speed=P("workers"),
            speed_norm=P("workers"),
            fail_round=P("workers"),
            delay_t=P("workers"),
        )

        def _any_shard(x):
            # scalar "any worker on any shard" — replicated across shards
            return jax.lax.psum(jnp.any(x).astype(jnp.int32), "workers") > 0

        def chunk_local(state: EngineState, consts: _ShardConsts):
            body = self._chunk_body(
                lambda st: self._sharded_round_step(st, consts), _any_shard
            )
            (state, _), infos = jax.lax.scan(
                body, (state, jnp.zeros((), bool)), None, length=length
            )
            return state, infos

        step = jax.jit(
            shard_map(
                chunk_local,
                mesh=mesh,
                in_specs=(state_specs, consts_specs),
                out_specs=(state_specs, infos_specs),
                check_rep=False,
            )
        )
        consts = _ShardConsts(
            speed=self._speed,
            speed_norm=self._speed_norm,
            fail_round=self._fail_round,
            # delay is stored [src, dst]; the step indexes [local dst, src]
            delay_t=jnp.transpose(self._delay),
        )
        return lambda state: step(state, consts)

    def _init_state(self) -> EngineState:
        state = super()._init_state()
        zi = jnp.zeros((self._n_dev,), jnp.int32)
        return state._replace(
            sent=zi,
            accepted=zi,
            discarded=zi,
            cost_total=jnp.zeros((self._n_dev,), jnp.float32),
        )

    def _gossip_bytes_per_round(self) -> int:
        p = self.worker.payload_bytes()
        w = self.config.n_workers
        if self.config.gossip_mode == "gated":
            # dense control plane (f32 cert + bool broadcast flag per
            # worker) + k candidate payloads per device, each carrying
            # an int32 global worker id
            k = min(int(self.config.gossip_top_k), self._w_local)
            return w * (4 + 1) + self._n_dev * k * (p + 4)
        # dense: model payload + f32 certificate + bool fired flag from
        # every worker, landing on every shard
        return w * (p + 4 + 1)

    def _gossip_mode(self) -> str:
        return self.config.gossip_mode

    # ------------------------------------------------------------------
    def _sharded_round_step(
        self, state: EngineState, consts: _ShardConsts
    ) -> tuple[EngineState, RoundInfo]:
        cfg = self.config
        w, depth, wl = cfg.n_workers, self._depth, self._w_local
        r = state.round
        row_idx = jnp.arange(wl)
        local_ids = jax.lax.axis_index("workers") * wl + row_idx  # global dst ids
        alive = state.alive & (r < consts.fail_round)

        # last round's post-scan certificates, carried in the state (no
        # third certificates() call per round)
        certs0 = state.certs  # (wl,)

        # --- 1. deliver arrivals due this round (all-local: the buffer
        # is destination-sharded with a global source axis) -----------------
        arr = state.inflight[:, :, 0]  # (wl dst, W src) certs
        arr_live = jnp.where(alive[:, None], arr, jnp.inf)
        best_src = jnp.argmin(arr_live, axis=1)  # (wl,) global src ids
        best_cert = arr_live[row_idx, best_src]
        take = accepts(certs0, best_cert, cfg.eps) & jnp.isfinite(best_cert)
        n_arrivals = jnp.sum(jnp.isfinite(arr), dtype=jnp.int32)
        n_taken = jnp.sum(take, dtype=jnp.int32)

        sent_slot = (r - consts.delay_t[row_idx, best_src]) % depth
        in_models = jax.tree_util.tree_map(
            lambda a: a[sent_slot, best_src], state.ring
        )

        def _adopt(operand):
            wstate, models, c, t = operand
            return self.worker.adopt_batch(wstate, models, c, t)

        # per-shard cond: a shard with no taker skips the adopt math
        wstate, adopt_cost = jax.lax.cond(
            jnp.any(take),
            _adopt,
            lambda operand: (operand[0], jnp.zeros((wl,), jnp.float32)),
            (state.worker, in_models, best_cert, take),
        )

        # --- 2. shift the in-flight buffer --------------------------------
        inflight = jnp.concatenate(
            [state.inflight[:, :, 1:], jnp.full((wl, w, 1), jnp.inf, jnp.float32)], axis=2
        )

        # --- 3. one segment per live, credit-covered local worker ---------
        credit = state.credit + consts.speed_norm
        active = alive & (credit >= 1.0 - 1e-6)
        credit = jnp.where(active, credit - 1.0, credit)

        need = self.worker.needs_resample(wstate) & active
        wstate, resample_cost = jax.lax.cond(
            jnp.any(need),
            lambda op: self.worker.resample_round(op[0], op[1]),
            lambda op: (op[0], jnp.zeros((wl,), jnp.float32)),
            (wstate, need),
        )
        scan_mask = active & ~need
        certs_pre = self.worker.certificates(wstate)
        wstate, scan_cost, fired = self.worker.scan_round(wstate, scan_mask)
        certs = self.worker.certificates(wstate)

        cost = adopt_cost + resample_cost + scan_cost
        clock = state.clock + cost / jnp.maximum(consts.speed, 1e-12)

        # --- 4+5. gossip: certificates + broadcast flags always gather
        # densely (the cheap control plane); model payloads gather for
        # every worker ("dense") or only for each device's top-k
        # locally-improved candidates ("gated") -----------------------------
        improved = fired & improves(certs_pre, certs, 0.0) & scan_mask
        if cfg.gossip_mode == "gated":
            k = min(int(cfg.gossip_top_k), wl)
            # top-k local improvers by certificate; stable sort so ties
            # break toward the lowest worker id, matching the delivery
            # argmin (this keeps gated == dense under uniform delay)
            score = jnp.where(improved, certs, jnp.inf)
            cand_rows = jnp.argsort(score, stable=True)[:k]  # (k,) local rows
            cand_valid = jnp.isfinite(score[cand_rows])  # actually improved
            bcast = jnp.zeros((wl,), bool).at[cand_rows].set(cand_valid)
            export_rows = getattr(self.worker, "export_payload_rows", None)
            cand_models = (
                export_rows(wstate, cand_rows)
                if export_rows is not None
                else jax.tree_util.tree_map(
                    lambda a: a[cand_rows], self.worker.export_models(wstate)
                )
            )
            # ONE collective: tiled gathers are per-leaf, so the (wl,)
            # control plane and the (k,) payload leg ride together —
            # at gated payload sizes the per-collective launch latency
            # is the cost that matters
            gathered = jax.lax.all_gather(
                {
                    "certs": certs,
                    "bcast": bcast,
                    # un-improved candidate slots point out of bounds so
                    # the ring scatter drops them
                    "ids": jnp.where(cand_valid, local_ids[cand_rows], w),
                    "models": cand_models,
                },
                "workers",
                axis=0,
                tiled=True,
            )  # certs/bcast: (W,); ids/models: (n_dev * k, ...)
            certs_all, bcast_all = gathered["certs"], gathered["bcast"]
            ring = jax.tree_util.tree_map(
                lambda buf, m: buf.at[r % depth, gathered["ids"]].set(m, mode="drop"),
                state.ring,
                gathered["models"],
            )
        else:
            gathered = jax.lax.all_gather(
                {
                    "certs": certs,
                    "improved": improved,
                    "models": self.worker.export_models(wstate),
                },
                "workers",
                axis=0,
                tiled=True,
            )
            certs_all, bcast_all = gathered["certs"], gathered["improved"]  # (W,)
            # ring writes gated to broadcasters (only their entries are
            # ever read back), mirroring the single-device engine
            ring = jax.tree_util.tree_map(
                lambda buf, m: buf.at[r % depth].set(
                    jnp.where(
                        bcast_all.reshape((-1,) + (1,) * (m.ndim - 1)),
                        m,
                        buf[r % depth],
                    )
                ),
                state.ring,
                gathered["models"],
            )

        d_idx = jnp.arange(depth)[None, None, :]
        # push_mask[local dst, global src, d]
        push_mask = (
            bcast_all[None, :, None]
            & alive[:, None, None]
            & (local_ids[:, None] != jnp.arange(w)[None, :])[:, :, None]
            & (d_idx == (consts.delay_t[:, :, None] - 1))
        )
        inflight = jnp.where(push_mask, certs_all[None, :, None], inflight)
        n_pushed = jnp.sum(push_mask, dtype=jnp.int32)

        new_state = EngineState(
            worker=wstate,
            certs=certs,
            alive=alive,
            credit=credit,
            clock=clock,
            inflight=inflight,
            ring=ring,
            round=r + 1,
            # (1,)-shaped per-shard partials; (n_dev,) globally
            sent=state.sent + n_pushed,
            accepted=state.accepted + n_taken,
            discarded=state.discarded + (n_arrivals - n_taken),
            cost_total=state.cost_total + jnp.sum(cost),
        )
        info = RoundInfo(
            certs=certs, changed=take | improved, clock=clock, alive=alive
        )
        return new_state, info


def sharded_engine_available(min_devices: int = 2) -> bool:
    """True when the current backend exposes enough devices to shard
    over (CI forces 8 host devices via ``XLA_FLAGS``); the sharded test
    modules key their skip conditions on this."""
    return len(jax.devices()) >= min_devices


__all__ = ["ShardedTMSNEngine", "sharded_engine_available"]
