"""The worker API: the single public contract every TMSN substrate runs.

The paper's claim is that the protocol applies to *any* iterative
learner that can (a) improve a model locally and (b) put a number on
how good it is. This module is that claim as code: the two worker
protocols — one per fidelity level — plus the helpers the engines use
to keep the contract minimal for implementers.

Two fidelity levels, one vocabulary:

  * :class:`TMSNWorker` — the event-driven simulator's worker
    (fidelity 1, :mod:`repro.core.simulator`): scalar state objects,
    one worker instance per logical machine, Python floats for
    certificates.
  * :class:`BatchedTMSNWorker` — the round engines' worker
    (fidelity 2/3, :mod:`repro.core.engine` /
    :mod:`repro.core.engine_sharded`): all W workers stacked into one
    pytree with a leading ``(W,)`` axis, advanced one segment per round
    inside a single jitted computation.

Implementations: :class:`repro.boosting.batched_sparrow.BatchedSparrowWorker`
(the paper's boosting learner) and
:class:`repro.core.sgd_worker.BatchedSGDWorker` (transformer + AdamW —
TMSN as an async data-parallel training strategy).
``tests/test_worker_contract.py`` is the reusable conformance harness;
run it against any new worker before trusting a run.

Contract requirements (the engines silently assume all of them):

  * **Purity.** Every method must be pure and traceable — the engine
    jits whole round chunks with the worker computation inlined. No
    Python side effects, no data-dependent Python control flow.
  * **Leading worker axis.** Every per-worker quantity — including
    per-worker *constants* like feature-ownership masks and the PRNG
    streams — lives in the state pytree with a leading ``(W,)`` axis
    and shards with it. Inside the sharded engine's ``shard_map`` the
    methods see *local* shards (leading axis ``W_local``), so nothing
    per-worker may be closed over, and global worker identity must
    never be synthesized from a leaf's leading dimension.
  * **Masking.** ``scan_round`` / ``adopt_batch`` / ``resample_round``
    take per-worker masks; masked-out workers must come back bitwise
    unchanged with zero cost (the engines encode fail-stop and laggard
    credit as masks).
  * **Monotone certificates.** A scan may only keep or lower a
    worker's certificate, and adoption is accept-gated so it only
    lowers it. The gated-gossip and pod-mesh equivalence arguments
    lean on this (see :mod:`repro.core.engine_sharded`); a worker with
    a noisy estimate must carry the raw estimate separately and expose
    a monotone envelope (running minimum) as its certificate —
    :mod:`repro.core.sgd_worker` shows the pattern.
"""

from __future__ import annotations

from typing import Any, Protocol

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "TMSNWorker",
    "BatchedTMSNWorker",
    "masked_rows",
    "has_resample_hooks",
    "export_payload_rows",
    "payload_bytes_from_export",
    "resolve_payload_bytes",
]


class TMSNWorker(Protocol):
    """Duck-typed worker plugged into the event-driven simulator.

    State objects are opaque to the simulator; certificates are floats
    (lower = better).
    """

    def init_state(self, worker_id: int, seed: int) -> Any: ...

    def run_segment(self, state: Any) -> tuple[Any, float, bool]:
        """Run one scheduling quantum.

        Returns (new_state, cost_units, fired) where ``cost_units`` is
        the simulated compute cost of the segment (examples scanned,
        including any sampling pass) and ``fired`` is True if the worker
        found a better model during this segment.
        """
        ...

    def certificate(self, state: Any) -> float: ...

    def export_model(self, state: Any) -> Any: ...

    def adopt(self, state: Any, model: Any, certificate: float) -> Any:
        """Interrupt: replace (H, L) with the incoming pair."""
        ...

    def payload_bytes(self, model: Any) -> int: ...


class BatchedTMSNWorker(Protocol):
    """Duck-typed batched worker plugged into the round engines.

    All methods must be pure and traceable (the engine jits the whole
    round step, worker computation included). States are stacked
    pytrees with a leading worker axis; certificates are ``(W,)``
    float32 arrays (lower = better) and must be monotone non-increasing
    over rounds — see the module docstring for the full contract.

    Only the five required methods are mandatory. The optional members
    carry no-op / derived defaults: a worker may simply not define
    them (the engines probe with ``getattr`` via the module helpers
    below), or subclass this protocol to inherit the defaults
    explicitly.
    """

    # ----- required ----------------------------------------------------
    def init_batch(self, n_workers: int, seed: int) -> Any: ...

    def scan_round(self, state: Any, mask: jnp.ndarray) -> tuple[Any, jnp.ndarray, jnp.ndarray]:
        """Run one segment for every worker where ``mask`` is True.

        Returns (new_state, cost (W,), fired (W,)); masked-out workers
        must come back unchanged with zero cost.
        """
        ...

    def certificates(self, state: Any) -> jnp.ndarray: ...

    def export_models(self, state: Any) -> Any:
        """Stacked model pytree with leading worker axis (the broadcast
        payload; must be cheap — no recomputation). Leaves may be any
        shape/dtype: the engines' snapshot ring and payload accounting
        are derived from this pytree, never assumed."""
        ...

    def adopt_batch(
        self, state: Any, models: Any, certs: jnp.ndarray, take: jnp.ndarray
    ) -> tuple[Any, jnp.ndarray]:
        """Adopt ``models[i]``/``certs[i]`` wherever ``take[i]``;
        returns (new_state, cost (W,)). Must be the identity (zero
        cost) where ``take`` is False — the engines rely on this to
        skip or fuse the adopt step."""
        ...

    # ----- optional: sampling-phase hooks (no-op defaults) -------------
    def needs_resample(self, state: Any) -> jnp.ndarray:
        """(W,) bool — workers whose next segment is a resample.
        Workers without a sampling phase simply omit BOTH resample
        hooks; the engines then skip the resample plumbing entirely
        (:func:`has_resample_hooks`)."""
        return jnp.zeros_like(self.certificates(state), dtype=bool)

    def resample_round(self, state: Any, do: jnp.ndarray) -> tuple[Any, jnp.ndarray]:
        """Spend the segment of every worker where ``do`` on a resample;
        returns (new_state, cost (W,))."""
        return state, jnp.zeros_like(self.certificates(state), dtype=jnp.float32)

    # ----- optional: payload hooks (derived defaults) ------------------
    def export_payload_rows(self, state: Any, rows: jnp.ndarray) -> Any:
        """Gather just ``rows`` (a (k,) int array of worker-axis
        indices) of the broadcast payload. The sharded engine's
        candidate-selecting tiers use it — gated gossip ships only the
        top-k locally-improved candidate models instead of the full
        stack, and the pod-mesh cross-pod tier ships the top-k pending
        candidates per flush. Workers that omit it get the shared
        indexing fallback (:func:`export_payload_rows`, this default)."""
        return jax.tree_util.tree_map(lambda a: a[rows], self.export_models(state))

    def payload_bytes(self) -> int:
        """Per-worker broadcast payload size in bytes. Optional: when a
        worker omits it the engines derive the size from the exported
        model pytree itself (:func:`payload_bytes_from_export`), which
        cannot drift from reality; define it only when the logical wire
        format differs from the exported leaves."""
        raise NotImplementedError  # engines derive via resolve_payload_bytes


def masked_rows(cond: jnp.ndarray, new: Any, old: Any) -> Any:
    """Per-worker select over a stacked pytree: broadcast the ``(W,)``
    cond over each leaf's trailing dims. The canonical way to satisfy
    the contract's "masked-out workers come back bitwise unchanged"."""
    return jax.tree_util.tree_map(
        lambda a, b: jnp.where(cond.reshape(cond.shape + (1,) * (a.ndim - 1)), a, b),
        new,
        old,
    )


def has_resample_hooks(worker: BatchedTMSNWorker) -> bool:
    """True when the worker implements BOTH sampling-phase hooks. The
    engines check this once at build time and statically omit the
    resample branch from the round step for workers without a sampling
    phase — no per-round cond on an all-False vector."""
    return callable(getattr(worker, "needs_resample", None)) and callable(
        getattr(worker, "resample_round", None)
    )


def export_payload_rows(worker: BatchedTMSNWorker, state: Any, rows: jnp.ndarray) -> Any:
    """Candidate payloads for ``rows`` via the worker's optional
    ``export_payload_rows`` hook, falling back to indexing the full
    exported stack. The one shared fallback every engine tier uses."""
    hook = getattr(worker, "export_payload_rows", None)
    if hook is not None:
        return hook(state, rows)
    return jax.tree_util.tree_map(lambda a: a[rows], worker.export_models(state))


def payload_bytes_from_export(
    worker: BatchedTMSNWorker, n_workers: int, seed: int = 0
) -> int:
    """Per-worker payload bytes derived from the exported model pytree.

    ``jax.eval_shape`` traces ``export_models(init_batch(...))``
    abstractly — no arrays are materialized, so this is cheap even for
    transformer-sized workers — and the per-worker size is the summed
    leaf footprint divided by W. Because it measures the actual export,
    it cannot drift from the wire format the way a hand-maintained
    constant can (the Sparrow worker's hand value is pinned against
    this in tests)."""
    shapes = jax.eval_shape(lambda: worker.export_models(worker.init_batch(n_workers, seed)))
    total = sum(
        int(np.prod(leaf.shape)) * np.dtype(leaf.dtype).itemsize
        for leaf in jax.tree_util.tree_leaves(shapes)
    )
    return total // max(n_workers, 1)


def resolve_payload_bytes(
    worker: BatchedTMSNWorker, n_workers: int, seed: int = 0
) -> int:
    """The payload size the engines account traffic with: the worker's
    own ``payload_bytes()`` when it defines one, else derived from the
    exported pytree."""
    hook = getattr(worker, "payload_bytes", None)
    # the Protocol default raises NotImplementedError; treat a worker
    # that inherited it (or omitted the method) identically
    if callable(hook) and getattr(hook, "__func__", hook) is not BatchedTMSNWorker.payload_bytes:
        return int(hook())
    return payload_bytes_from_export(worker, n_workers, seed)
