"""Event-driven discrete-event simulator for the TMSN protocol.

This is fidelity level 1 of DESIGN.md §3: the paper's protocol
*exactly* — independent workers with different speeds, fire-and-forget
broadcast with per-link latencies, interrupt-on-accept, laggards and
fail-stop machines — with simulated wall-clock time driven by a cost
model (examples scanned / worker speed), which mirrors the CPU-bound
regime of the paper's experiments.

The actual learning computation inside each worker event is real JAX
(the Sparrow scanner / sampler); only *time* is simulated, because this
container has one CPU and the paper's claims are about scaling across
machines.

Interrupt granularity: a worker is scheduled in *segments* (a bounded
number of examples). An accepted message takes effect at the end of the
in-flight segment and discards that segment's partial scan — a
conservative model of the paper's per-example interrupt check.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Any, Callable, Sequence

from repro.core.protocol import accepts, improves
from repro.core.result import SimResult, TrafficCounters
from repro.core.worker import TMSNWorker

__all__ = [
    "TMSNWorker",  # re-exported; the worker protocols live in repro.core.worker
    "WorkerSpec",
    "SimulatorConfig",
    "SimResult",  # re-exported; lives in repro.core.result
    "TMSNSimulator",
    "run_bsp_baseline",
]


@dataclasses.dataclass(frozen=True)
class WorkerSpec:
    """Per-machine heterogeneity knobs."""

    speed: float = 1.0  # cost units per simulated second
    fail_at: float | None = None  # fail-stop time (None = never)


@dataclasses.dataclass(frozen=True)
class SimulatorConfig:
    n_workers: int = 4
    eps: float = 0.0  # protocol gap; 0 = "any strict improvement"
    base_latency: float = 0.05  # seconds, per broadcast hop
    latency_jitter: float = 0.02  # uniform [0, jitter) extra per hop
    max_time: float = 1e9
    max_events: int = 2_000_000
    seed: int = 0
    # Stop as soon as any live worker's certificate <= target (None = run
    # until max_time/max_events).
    target_certificate: float | None = None
    #: snapshot the current best model every N processed events
    #: (0 = off); snapshots land in SimResult.snapshots
    snapshot_every: int = 0


_RESUME, _RECV = 0, 1


class TMSNSimulator:
    """Discrete-event TMSN run over a set of logical workers."""

    def __init__(
        self,
        worker: TMSNWorker,
        specs: Sequence[WorkerSpec],
        config: SimulatorConfig,
        latency_fn: Callable[[int, int, float], float] | None = None,
    ) -> None:
        if len(specs) != config.n_workers:
            raise ValueError(f"{len(specs)} specs for {config.n_workers} workers")
        self.worker = worker
        self.specs = list(specs)
        self.config = config
        self._latency_fn = latency_fn
        # deterministic per-run pseudo randomness for latency jitter
        import random

        self._rng = random.Random(config.seed)

    def _latency(self, src: int, dst: int, now: float) -> float:
        if self._latency_fn is not None:
            return self._latency_fn(src, dst, now)
        return self.config.base_latency + self._rng.random() * self.config.latency_jitter

    def run(self) -> SimResult:
        cfg = self.config
        states = [self.worker.init_state(i, cfg.seed + 1000 * i) for i in range(cfg.n_workers)]
        certs = [float(self.worker.certificate(s)) for s in states]
        alive = [True] * cfg.n_workers

        heap: list[tuple[float, int, int, int, Any]] = []
        counter = 0
        for i in range(cfg.n_workers):
            heapq.heappush(heap, (0.0, counter, _RESUME, i, None))
            counter += 1

        history: list[tuple[float, int, float]] = [(0.0, i, certs[i]) for i in range(cfg.n_workers)]
        snapshots: list = []
        traffic = TrafficCounters()
        cost_total = 0.0
        events = 0
        now = 0.0

        def done() -> bool:
            if cfg.target_certificate is None:
                return False
            return any(
                certs[i] <= cfg.target_certificate for i in range(cfg.n_workers) if alive[i]
            )

        while heap and events < cfg.max_events and now <= cfg.max_time and not done():
            now, _, kind, wid, payload = heapq.heappop(heap)
            events += 1
            if cfg.snapshot_every and events % cfg.snapshot_every == 0:
                b = min(range(cfg.n_workers), key=lambda i: certs[i])
                snapshots.append((now, certs[b], self.worker.export_model(states[b])))
            spec = self.specs[wid]
            if spec.fail_at is not None and now >= spec.fail_at:
                alive[wid] = False
            if not alive[wid]:
                continue

            if kind == _RECV:
                in_model, in_cert = payload
                if accepts(certs[wid], in_cert, cfg.eps):
                    states[wid] = self.worker.adopt(states[wid], in_model, in_cert)
                    certs[wid] = float(in_cert)
                    traffic.accepted += 1
                    history.append((now, wid, certs[wid]))
                else:
                    traffic.discarded += 1
                continue

            # _RESUME: run one scheduling quantum of real computation.
            old_cert = certs[wid]
            states[wid], cost, fired = self.worker.run_segment(states[wid])
            cost_total += cost
            elapsed = cost / max(spec.speed, 1e-12)
            t_end = now + elapsed

            if fired:
                new_cert = float(self.worker.certificate(states[wid]))
                certs[wid] = new_cert
                history.append((t_end, wid, new_cert))
                # Broadcast on ANY strict improvement (MainAlgorithm:
                # "when H is updated ... broadcast"); the gap eps gates
                # only ACCEPTANCE. Gating broadcasts by eps deadlocks
                # feature-partitioned workers once per-fire certificate
                # deltas drop below eps (measured — EXPERIMENTS.md §Repro).
                if improves(old_cert, new_cert, 0.0):
                    model = self.worker.export_model(states[wid])
                    nbytes = self.worker.payload_bytes(model)
                    for dst in range(cfg.n_workers):
                        if dst == wid or not alive[dst]:
                            continue
                        lat = self._latency(wid, dst, t_end)
                        heapq.heappush(
                            heap, (t_end + lat, counter, _RECV, dst, (model, new_cert))
                        )
                        counter += 1
                        traffic.sent += 1
                        traffic.bytes_broadcast += nbytes

            heapq.heappush(heap, (t_end, counter, _RESUME, wid, None))
            counter += 1

        return SimResult.from_traffic(
            traffic,
            history=history,
            final_certificates=certs,
            final_models=[self.worker.export_model(s) for s in states],
            sim_time=now,
            cost_units_total=cost_total,
            events_processed=events,
            snapshots=snapshots,
        )


def run_bsp_baseline(
    worker: TMSNWorker,
    specs: Sequence[WorkerSpec],
    config: SimulatorConfig,
    rounds: int,
) -> SimResult:
    """Bulk-synchronous contrast harness (paper §1's strawman).

    All workers run one segment per round; the round ends when the
    *slowest* live worker finishes (the barrier); then the best model is
    distributed to everyone. Wall-clock per round = max_i(cost_i /
    speed_i) + one broadcast latency. This quantifies the laggard
    penalty TMSN removes.
    """
    states = [worker.init_state(i, config.seed + 1000 * i) for i in range(config.n_workers)]
    certs = [float(worker.certificate(s)) for s in states]
    alive = [True] * config.n_workers
    history = [(0.0, i, certs[i]) for i in range(config.n_workers)]
    now = 0.0
    cost_total = 0.0
    wait = [0.0] * config.n_workers
    sent = 0
    for _ in range(rounds):
        durations = []
        for i in range(config.n_workers):
            if alive[i] and specs[i].fail_at is not None and now >= specs[i].fail_at:
                alive[i] = False
            if not alive[i]:
                durations.append(0.0)
                continue
            states[i], cost, fired = worker.run_segment(states[i])
            cost_total += cost
            durations.append(cost / max(specs[i].speed, 1e-12))
            if fired:
                certs[i] = float(worker.certificate(states[i]))
        # A failed worker that never reports stalls the barrier until a
        # timeout; model it as the max duration of live workers (the
        # charitable reading — real BSP is worse).
        round_len = max(durations) if durations else 0.0
        for i in range(config.n_workers):
            if alive[i]:
                wait[i] += round_len - durations[i]
        now += round_len + config.base_latency
        best = min(range(config.n_workers), key=lambda i: certs[i])
        best_model = worker.export_model(states[best])
        for i in range(config.n_workers):
            if i != best and alive[i] and accepts(certs[i], certs[best], config.eps):
                states[i] = worker.adopt(states[i], best_model, certs[best])
                certs[i] = certs[best]
                sent += 1
        history.append((now, best, certs[best]))
        if config.target_certificate is not None and certs[best] <= config.target_certificate:
            break
    return SimResult(
        history=history,
        final_certificates=certs,
        final_models=[worker.export_model(s) for s in states],
        sim_time=now,
        messages_sent=sent,
        messages_accepted=sent,
        messages_discarded=0,
        bytes_broadcast=0,
        cost_units_total=cost_total,
        events_processed=rounds,
        wait_time=wait,
    )
