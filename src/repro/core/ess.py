"""Effective sample size for weighted samples (paper Eq. 4).

``n_eff = (sum w)^2 / sum(w^2)``.

As boosting progresses the weights of the in-memory sample become
skewed, the effective sample size shrinks, and the stopping rule needs
ever more raw examples to certify an edge. When ``n_eff / m`` falls
below a threshold the Sampler draws a fresh uniform-weight sample.
"""

from __future__ import annotations

import jax.numpy as jnp


def effective_sample_size(w: jnp.ndarray, mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Effective number of examples of an (un-normalized) weight vector.

    Args:
        w: weights, any shape (flattened internally); need not be normalized.
        mask: optional boolean/0-1 mask of live entries (same shape as ``w``).

    Returns:
        scalar ``(sum w)^2 / sum w^2``; 0 when all weights are 0.
    """
    w = jnp.asarray(w, dtype=jnp.float32).ravel()
    if mask is not None:
        w = w * jnp.asarray(mask, dtype=jnp.float32).ravel()
    s1 = jnp.sum(w)
    s2 = jnp.sum(w * w)
    return jnp.where(s2 > 0, (s1 * s1) / jnp.maximum(s2, 1e-30), 0.0)


def expected_sample_fraction(w: jnp.ndarray) -> jnp.ndarray:
    """Paper §3, last paragraph: expected fraction of examples selected by
    selective sampling with acceptance probability proportional to ``w``:
    ``mean(w) / max(w)``."""
    w = jnp.asarray(w, dtype=jnp.float32).ravel()
    return jnp.where(w.size > 0, jnp.mean(w) / jnp.maximum(jnp.max(w), 1e-30), 0.0)
