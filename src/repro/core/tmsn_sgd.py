"""TMSN-SGD: the paper's protocol as a distributed *training strategy*
for the transformer zoo (DESIGN.md §3, fidelity level 3).

Mapping of the paper's concepts onto SPMD/TPU:

  worker            -> a worker *group*: a slice of the mesh along the
                       worker axis ("data" single-pod, "pod" multi-pod)
  independent search-> K local optimizer steps on the group's own batch
                       shard (no gradient all-reduce across groups)
  certificate L     -> EMA of training loss + a concentration width
                       (std of the K step losses / sqrt(K); the honest
                       analogue of the paper's bound — DESIGN.md notes
                       that a training-loss EMA is an estimator, not a
                       sound bound)
  broadcast (H,L)   -> one conditional one-hot parameter exchange per
                       round: the argmin-certificate group's params are
                       gathered (XLA lowers the dynamic index over the
                       worker-sharded axis to a collective) and adopted
                       only by groups whose certificate it beats by eps
  accept/reject     -> repro.core.protocol.accepts, unchanged

Collective cost per round: ONE parameter broadcast over the worker axis
instead of K gradient all-reduces — this is precisely the paper's
"communicate only when you have something new" applied to SGD, and it
attacks the collective roofline term (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models import loss_fn
from repro.optim import AdamWConfig, apply_updates


@dataclasses.dataclass(frozen=True)
class TMSNSGDConfig:
    num_workers: int = 16  # W — size of the worker mesh axis
    local_steps: int = 8  # K — steps between exchange opportunities
    eps: float = 0.0  # protocol gap on the certificate
    ema: float = 0.9
    width_coef: float = 1.0  # certificate confidence-width multiplier
    unroll: bool = False  # unroll the K-step scan (dry-run cost analysis)


def make_tmsn_round(
    cfg: ArchConfig, opt_cfg: AdamWConfig, tcfg: TMSNSGDConfig
) -> Callable:
    """Returns round(params_w, opt_w, cert_w, batch_w) — all carrying a
    leading W (worker) axis; batch_w leaves are (W, K, local_batch, ...)."""

    def per_worker(params, opt_state, batches):
        def one_step(carry, batch):
            p, o = carry

            def loss_only(pp):
                loss, metrics = loss_fn(pp, cfg, batch)
                return loss, metrics

            (loss, _metrics), grads = jax.value_and_grad(loss_only, has_aux=True)(p)
            p, o = apply_updates(p, grads, o, opt_cfg)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            one_step, (params, opt_state), batches,
            unroll=tcfg.local_steps if tcfg.unroll else 1,
        )
        return params, opt_state, losses

    def tmsn_round(params_w, opt_w, cert_w, batch_w):
        params_w, opt_w, losses_w = jax.vmap(per_worker)(params_w, opt_w, batch_w)
        # certificate: loss EMA + concentration width over the K steps
        mean_w = jnp.mean(losses_w, axis=1)
        width = tcfg.width_coef * jnp.std(losses_w, axis=1) / jnp.sqrt(
            jnp.asarray(tcfg.local_steps, jnp.float32)
        )
        cert_new = tcfg.ema * cert_w + (1.0 - tcfg.ema) * (mean_w + width)

        best = jnp.argmin(cert_new)
        best_cert = cert_new[best]
        # accept/reject per worker (repro.core.protocol.accepts, inlined
        # for jit: strict improvement by more than eps)
        adopt = best_cert < cert_new - tcfg.eps  # (W,) bool

        def adopt_leaf(a):
            winner = jax.lax.dynamic_index_in_dim(a, best, 0, keepdims=True)
            mask = adopt.reshape((-1,) + (1,) * (a.ndim - 1))
            return jnp.where(mask, jnp.broadcast_to(winner, a.shape), a)

        params_w = jax.tree.map(adopt_leaf, params_w)
        opt_w = jax.tree.map(adopt_leaf, opt_w)
        cert_w = jnp.where(adopt, best_cert, cert_new)
        return params_w, opt_w, cert_w, jnp.mean(losses_w)

    return tmsn_round


def init_tmsn_state(
    cfg: ArchConfig, opt_cfg: AdamWConfig, tcfg: TMSNSGDConfig, key: jax.Array
) -> tuple[Any, Any, jnp.ndarray]:
    """(params_w, opt_w, cert_w) with the leading W axis. Workers start
    from the SAME initial model (paper §2: all workers start from H_0);
    divergence comes from their independent batch shards."""
    from repro.models import init_params
    from repro.optim import init_opt_state

    params = init_params(cfg, key)
    opt = init_opt_state(params, opt_cfg)
    W = tcfg.num_workers
    params_w = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (W,) + a.shape), params)
    opt_w = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (W,) + a.shape), opt)
    cert_w = jnp.full((W,), jnp.inf, jnp.float32)
    # inf EMA poisons the update; start from a large finite sentinel
    cert_w = jnp.full((W,), 1e9, jnp.float32)
    return params_w, opt_w, cert_w


def tmsn_batch_specs(cfg: ArchConfig, tcfg: TMSNSGDConfig, seq: int, global_batch: int):
    """ShapeDtypeStructs for one round's batches: (W, K, b_local, ...)."""
    W, K = tcfg.num_workers, tcfg.local_steps
    b_local = max(global_batch // W, 1)
    spec = {
        "tokens": jax.ShapeDtypeStruct((W, K, b_local, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((W, K, b_local, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct((W, K, b_local, seq), jnp.float32),
    }
    if cfg.frontend is not None:
        spec["frontend_embeds"] = jax.ShapeDtypeStruct(
            (W, K, b_local, cfg.frontend_len, cfg.frontend_dim), jnp.float32
        )
    return spec
