"""TMSN-SGD: shared config, the simulator-fidelity oracle, and the
legacy synchronous analysis round.

The *engine-hosted* TMSN-SGD worker lives in
:mod:`repro.core.sgd_worker` (``BatchedSGDWorker`` /
``lm_sgd_worker``): it implements the
:class:`repro.core.worker.BatchedTMSNWorker` contract, so the full
substrate chain — ``TMSNEngine``, ``ShardedTMSNEngine``, gated gossip,
the pod mesh, the sparse in-flight state — drives SGD learners with no
SGD-specific engine code. What remains here:

  * :class:`TMSNSGDConfig` — the knob set both paths share
    (``local_steps`` K, certificate ``ema`` / ``width_coef``,
    ``unroll``; ``num_workers`` / ``eps`` feed only the legacy round —
    the engines own W and the acceptance gate);
  * :func:`make_oracle_round` / :func:`oracle_run` — a dense,
    delay-1, uniform-speed synchronous exchange built on any batched
    worker's own methods, mirroring the engine's round order exactly
    (deliver -> adopt -> segment -> broadcast-on-strict-improvement).
    Under that config the engine's in-flight buffer holds at most one
    round of messages, so carrying last round's (certs, models) between
    iterations IS the buffer — the oracle is the worker-level analogue
    of the event simulator, and ``tests/test_worker_contract.py`` pins
    both engines against it;
  * the legacy fused round (:func:`make_tmsn_round` /
    :func:`init_tmsn_state` / :func:`tmsn_batch_specs`) — a
    barrier-synchronous one-hot exchange kept for the launch/dry-run
    cost analysis (``launch/dryrun.py``, ``launch/train.py``), where
    the object of study is the per-round collective footprint, not the
    asynchronous protocol.

Collective cost per round: ONE parameter broadcast over the worker axis
instead of K gradient all-reduces — this is precisely the paper's
"communicate only when you have something new" applied to SGD, and it
attacks the collective roofline term (EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import accepts, improves
from repro.core.worker import has_resample_hooks
from repro.models.config import ArchConfig
from repro.models import loss_fn
from repro.optim import AdamWConfig, apply_updates


@dataclasses.dataclass(frozen=True)
class TMSNSGDConfig:
    num_workers: int = 16  # W — size of the worker mesh axis
    local_steps: int = 8  # K — steps between exchange opportunities
    eps: float = 0.0  # protocol gap on the certificate
    ema: float = 0.9
    width_coef: float = 1.0  # certificate confidence-width multiplier
    unroll: bool = False  # unroll the K-step scan (dry-run cost analysis)


# ---------------------------------------------------------------------------
# Simulator-fidelity oracle: dense delay-1 uniform-speed exchange over any
# batched worker. tests/test_worker_contract.py pins both engines against it.
# ---------------------------------------------------------------------------


def make_oracle_round(worker: Any, eps: float = 0.0) -> Callable:
    """Returns ``round(state, bcast_certs, bcast_models) -> (state,
    certs, bcast_certs, bcast_models)`` — one synchronous round of the
    dense, delay-1, uniform-speed, no-failure protocol over ``worker``
    (any :class:`repro.core.worker.BatchedTMSNWorker`).

    ``bcast_certs`` (W,) carries last round's broadcast certificates
    (+inf where a worker did not fire) and ``bcast_models`` the matching
    export — together they are the engine's one-deep in-flight buffer +
    snapshot ring collapsed to the only slot that can be occupied under
    this config. Stage order and tie-breaks mirror
    ``TMSNEngine._round_step`` exactly:

      1. deliver: per-destination argmin over sources (self excluded,
         ties to the LOWEST source id — ``jnp.argmin``'s first-minimum,
         same as the engine's), accept iff the incoming certificate
         beats the local one by more than ``eps``;
      2. adopt_batch — called unconditionally: the contract requires
         identity (at zero cost) where ``take`` is False, which is what
         makes the engine's ``lax.cond`` skip bit-equal to this;
      3. resample (only if the worker defines the optional hooks),
         then one segment for every worker;
      4. broadcast on STRICT improvement of the certificate (eps gates
         acceptance only).
    """
    use_resample = has_resample_hooks(worker)

    def round_fn(state: Any, bcast_certs: jnp.ndarray, bcast_models: Any):
        w = bcast_certs.shape[0]
        dst = jnp.arange(w)
        # --- 1. deliver last round's broadcasts (delay 1) ---------------
        cand = jnp.where(
            dst[:, None] == dst[None, :], jnp.inf, bcast_certs[None, :]
        )  # (dst, src), self masked
        best_src = jnp.argmin(cand, axis=1)
        best_cert = cand[dst, best_src]
        local = worker.certificates(state)
        take = accepts(local, best_cert, eps) & jnp.isfinite(best_cert)
        in_models = jax.tree_util.tree_map(lambda a: a[best_src], bcast_models)
        # --- 2. adopt ----------------------------------------------------
        state, _ = worker.adopt_batch(state, in_models, best_cert, take)
        # --- 3. one segment per worker (all active: uniform speed) -------
        if use_resample:
            need = worker.needs_resample(state)
            state, _ = jax.lax.cond(
                jnp.any(need),
                lambda op: worker.resample_round(op[0], op[1]),
                lambda op: (op[0], jnp.zeros((w,), jnp.float32)),
                (state, need),
            )
            scan_mask = ~need
        else:
            scan_mask = jnp.ones((w,), bool)
        certs_pre = worker.certificates(state)
        state, _, fired = worker.scan_round(state, scan_mask)
        certs = worker.certificates(state)
        # --- 4. broadcast strict improvements ----------------------------
        improved = fired & improves(certs_pre, certs, 0.0) & scan_mask
        bcast_certs = jnp.where(improved, certs, jnp.inf)
        # non-improved rows of the export are dead payload (their certs
        # are +inf, delivery can never select them) — carrying the full
        # fresh export is the ring's snapshot-at-broadcast-round exactly
        bcast_models = worker.export_models(state)
        return state, certs, bcast_certs, bcast_models

    return round_fn


@dataclasses.dataclass
class OracleResult:
    state: Any  # final batched worker state
    certs: np.ndarray  # (W,) final certificates
    history: np.ndarray  # (rounds, W) post-round certificates
    rounds: int


def oracle_run(
    worker: Any,
    n_workers: int,
    max_rounds: int,
    eps: float = 0.0,
    seed: int = 0,
    target_certificate: float | None = None,
) -> OracleResult:
    """Run :func:`make_oracle_round` from ``worker.init_batch`` until
    ``max_rounds`` or any certificate crosses ``target_certificate``
    (f32 compare, matching the engine's in-scan stop)."""
    state = worker.init_batch(n_workers, seed)
    bcast_certs = jnp.full((n_workers,), jnp.inf, jnp.float32)
    bcast_models = worker.export_models(state)
    round_fn = jax.jit(make_oracle_round(worker, eps))
    history = []
    rounds = 0
    for _ in range(max_rounds):
        state, certs, bcast_certs, bcast_models = round_fn(
            state, bcast_certs, bcast_models
        )
        history.append(np.asarray(certs))
        rounds += 1
        if target_certificate is not None and bool(
            np.any(np.asarray(certs) <= np.float32(target_certificate))
        ):
            break
    final = np.asarray(worker.certificates(state))
    return OracleResult(
        state=state, certs=final, history=np.stack(history), rounds=rounds
    )


def make_tmsn_round(
    cfg: ArchConfig, opt_cfg: AdamWConfig, tcfg: TMSNSGDConfig
) -> Callable:
    """Returns round(params_w, opt_w, cert_w, batch_w) — all carrying a
    leading W (worker) axis; batch_w leaves are (W, K, local_batch, ...)."""

    def per_worker(params, opt_state, batches):
        def one_step(carry, batch):
            p, o = carry

            def loss_only(pp):
                loss, metrics = loss_fn(pp, cfg, batch)
                return loss, metrics

            (loss, _metrics), grads = jax.value_and_grad(loss_only, has_aux=True)(p)
            p, o = apply_updates(p, grads, o, opt_cfg)
            return (p, o), loss

        (params, opt_state), losses = jax.lax.scan(
            one_step, (params, opt_state), batches,
            unroll=tcfg.local_steps if tcfg.unroll else 1,
        )
        return params, opt_state, losses

    def tmsn_round(params_w, opt_w, cert_w, batch_w):
        params_w, opt_w, losses_w = jax.vmap(per_worker)(params_w, opt_w, batch_w)
        # certificate: loss EMA + concentration width over the K steps
        mean_w = jnp.mean(losses_w, axis=1)
        width = tcfg.width_coef * jnp.std(losses_w, axis=1) / jnp.sqrt(
            jnp.asarray(tcfg.local_steps, jnp.float32)
        )
        cert_new = tcfg.ema * cert_w + (1.0 - tcfg.ema) * (mean_w + width)

        best = jnp.argmin(cert_new)
        best_cert = cert_new[best]
        # accept/reject per worker (repro.core.protocol.accepts, inlined
        # for jit: strict improvement by more than eps)
        adopt = best_cert < cert_new - tcfg.eps  # (W,) bool

        def adopt_leaf(a):
            winner = jax.lax.dynamic_index_in_dim(a, best, 0, keepdims=True)
            mask = adopt.reshape((-1,) + (1,) * (a.ndim - 1))
            return jnp.where(mask, jnp.broadcast_to(winner, a.shape), a)

        params_w = jax.tree.map(adopt_leaf, params_w)
        opt_w = jax.tree.map(adopt_leaf, opt_w)
        cert_w = jnp.where(adopt, best_cert, cert_new)
        return params_w, opt_w, cert_w, jnp.mean(losses_w)

    return tmsn_round


def init_tmsn_state(
    cfg: ArchConfig, opt_cfg: AdamWConfig, tcfg: TMSNSGDConfig, key: jax.Array
) -> tuple[Any, Any, jnp.ndarray]:
    """(params_w, opt_w, cert_w) with the leading W axis. Workers start
    from the SAME initial model (paper §2: all workers start from H_0);
    divergence comes from their independent batch shards."""
    from repro.models import init_params
    from repro.optim import init_opt_state

    params = init_params(cfg, key)
    opt = init_opt_state(params, opt_cfg)
    W = tcfg.num_workers
    params_w = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (W,) + a.shape), params)
    opt_w = jax.tree.map(lambda a: jnp.broadcast_to(a[None], (W,) + a.shape), opt)
    cert_w = jnp.full((W,), jnp.inf, jnp.float32)
    # inf EMA poisons the update; start from a large finite sentinel
    cert_w = jnp.full((W,), 1e9, jnp.float32)
    return params_w, opt_w, cert_w


def tmsn_batch_specs(cfg: ArchConfig, tcfg: TMSNSGDConfig, seq: int, global_batch: int):
    """ShapeDtypeStructs for one round's batches: (W, K, b_local, ...)."""
    W, K = tcfg.num_workers, tcfg.local_steps
    b_local = max(global_batch // W, 1)
    spec = {
        "tokens": jax.ShapeDtypeStruct((W, K, b_local, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((W, K, b_local, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct((W, K, b_local, seq), jnp.float32),
    }
    if cfg.frontend is not None:
        spec["frontend_embeds"] = jax.ShapeDtypeStruct(
            (W, K, b_local, cfg.frontend_len, cfg.frontend_dim), jnp.float32
        )
    return spec
