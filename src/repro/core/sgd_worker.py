"""TMSN-SGD as a first-class engine worker: transformer + AdamW on the
gossip substrate.

:class:`BatchedSGDWorker` adapts any ``(init_fn, loss_fn, batch_fn,
AdamWConfig)`` quadruple to the
:class:`repro.core.worker.BatchedTMSNWorker` contract, so the whole
substrate chain — ``TMSNEngine``, ``ShardedTMSNEngine``, dense/gated
gossip, the pod mesh, the sparse in-flight state — runs SGD learners
unchanged. This is the paper's async setting applied to data-parallel
LM training: gradients never cross the wire, only improved parameter
snapshots do.

Mapping onto the paper's concepts:

  one segment        -> ``local_steps`` (K) AdamW steps on the worker's
                        own synthetic batch stream (per-worker PRNG keys
                        carried IN the state, per the sharding contract)
  certificate L      -> running minimum of an EMA loss estimate plus a
                        concentration width (``std of the K step losses
                        / sqrt(K)``, scaled by ``width_coef``). The raw
                        EMA estimate is *not* monotone — batches are
                        noisy — so the state carries both: ``est`` (the
                        honest estimator) and ``cert = min(cert, est)``
                        (the monotone envelope the protocol requires).
                        ``fired`` is a strict decrease of the envelope.
  broadcast payload  -> the params pytree only. Optimizer moments stay
                        local: shipping them would double the wire
                        footprint, and an adopter continuing with its
                        own moments is the standard model-merging
                        choice. On adoption both ``cert`` and ``est``
                        restart at the incoming certificate (the SGD
                        analogue of Sparrow replacing (H, L)).
  cost units         -> K (local optimizer steps per segment); adoption
                        is charged zero (a parameter copy, no examples).

The worker deliberately omits every optional hook: no
``needs_resample``/``resample_round`` (engines drop the resample branch
statically), no ``payload_bytes`` (engines derive it from the exported
pytree via ``jax.eval_shape``), no ``export_payload_rows`` (gated and
cross-pod tiers use the shared indexing fallback) — it is the
conformance fixture for the contract's default machinery as much as a
trainer (``tests/test_worker_contract.py``).

The simulator-fidelity oracle lives in :mod:`repro.core.tmsn_sgd`
(``make_oracle_round`` / ``oracle_run``); the engine-hosted run is
pinned against it on the uniform-speed / zero-latency config.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.worker import masked_rows
from repro.optim import AdamWConfig, apply_updates, init_opt_state

__all__ = ["BatchedSGDState", "BatchedSGDWorker", "lm_sgd_worker"]


class BatchedSGDState(NamedTuple):
    """Stacked per-worker SGD state; every leaf has a leading (W,) axis
    (``opt``'s per-worker ``step`` scalar becomes a (W,) vector)."""

    params: Any  # model params, leaves (W, ...)
    opt: Any  # AdamW state {"mu", "nu", "step"}, leaves (W, ...)
    cert: jnp.ndarray  # (W,) f32 — monotone envelope (running min of est)
    est: jnp.ndarray  # (W,) f32 — raw EMA estimate (+inf before 1st segment)
    key: jax.Array  # (W, 2) per-worker batch-stream PRNG keys


class BatchedSGDWorker:
    """K local AdamW steps per segment under the worker contract.

    ``init_fn(key) -> params`` builds one (unbatched) model;
    ``loss_fn(params, batch) -> (loss, aux)`` is the per-step objective;
    ``batch_fn(key) -> batch`` draws one step's batch pytree (leaves
    ``(batch, ...)``) — it must be traceable, the stream advances inside
    the jitted round. ``local_steps``, ``ema``, ``width_coef`` and
    ``unroll`` come from :class:`repro.core.tmsn_sgd.TMSNSGDConfig`
    (its ``num_workers``/``eps`` only feed the legacy synchronous path:
    the engine decides W via ``EngineConfig.n_workers``, and eps gates
    acceptance in the engine, never inside the worker).
    """

    def __init__(
        self,
        init_fn: Callable[[jax.Array], Any],
        loss_fn: Callable[[Any, Any], tuple[jnp.ndarray, Any]],
        batch_fn: Callable[[jax.Array], Any],
        opt_cfg: AdamWConfig,
        sgd_cfg: "Any" = None,
    ) -> None:
        # deferred import: tmsn_sgd pulls the model zoo, this module
        # must stay importable from repro.core without it
        from repro.core.tmsn_sgd import TMSNSGDConfig

        self._init_fn = init_fn
        self._loss_fn = loss_fn
        self._batch_fn = batch_fn
        self._opt_cfg = opt_cfg
        self.cfg = TMSNSGDConfig() if sgd_cfg is None else sgd_cfg
        if self.cfg.local_steps < 1:
            raise ValueError(f"local_steps must be >= 1, got {self.cfg.local_steps}")

    # ----- contract: required ------------------------------------------
    def init_batch(self, n_workers: int, seed: int) -> BatchedSGDState:
        base = jax.random.PRNGKey(seed)
        params = self._init_fn(base)
        opt = init_opt_state(params, self._opt_cfg)

        def tile(a):
            return jnp.broadcast_to(a[None], (n_workers,) + a.shape)

        # every worker starts from the SAME H_0 (paper §2); divergence
        # comes from the independent per-worker batch streams below
        keys = jax.vmap(lambda i: jax.random.fold_in(base, i))(
            jnp.arange(1, n_workers + 1)
        )
        return BatchedSGDState(
            params=jax.tree_util.tree_map(tile, params),
            opt=jax.tree_util.tree_map(tile, opt),
            cert=jnp.full((n_workers,), jnp.inf, jnp.float32),
            est=jnp.full((n_workers,), jnp.inf, jnp.float32),
            key=keys,
        )

    def certificates(self, state: BatchedSGDState) -> jnp.ndarray:
        return state.cert

    def export_models(self, state: BatchedSGDState) -> Any:
        return state.params

    def scan_round(
        self, state: BatchedSGDState, mask: jnp.ndarray
    ) -> tuple[BatchedSGDState, jnp.ndarray, jnp.ndarray]:
        cfg = self.cfg
        k_steps = int(cfg.local_steps)

        def segment(params, opt, key):
            key, sub = jax.random.split(key)
            batches = jax.vmap(self._batch_fn)(jax.random.split(sub, k_steps))

            def one_step(carry, batch):
                p, o = carry
                (loss, _aux), grads = jax.value_and_grad(
                    self._loss_fn, has_aux=True
                )(p, batch)
                p, o = apply_updates(p, grads, o, self._opt_cfg)
                return (p, o), loss

            (params, opt), losses = jax.lax.scan(
                one_step, (params, opt), batches,
                unroll=k_steps if cfg.unroll else 1,
            )
            return params, opt, losses, key

        params, opt, losses, key = jax.vmap(segment)(
            state.params, state.opt, state.key
        )
        mean = jnp.mean(losses, axis=1)
        width = cfg.width_coef * jnp.std(losses, axis=1) / jnp.sqrt(
            jnp.asarray(k_steps, jnp.float32)
        )
        sample = (mean + width).astype(jnp.float32)
        # EMA warm-start: the first observation IS the estimate (an inf
        # or giant sentinel would poison the average for ~1/(1-ema)
        # rounds); afterwards the usual geometric update
        est = jnp.where(
            jnp.isfinite(state.est),
            cfg.ema * state.est + (1.0 - cfg.ema) * sample,
            sample,
        )
        cert = jnp.minimum(state.cert, est)  # monotone envelope
        new = BatchedSGDState(params=params, opt=opt, cert=cert, est=est, key=key)
        # masked-out workers come back bitwise unchanged (keys included:
        # their batch streams must not advance on skipped rounds)
        new = masked_rows(mask, new, state)
        cost = mask.astype(jnp.float32) * float(k_steps)
        fired = mask & (new.cert < state.cert)
        return new, cost, fired

    def adopt_batch(
        self,
        state: BatchedSGDState,
        models: Any,
        certs: jnp.ndarray,
        take: jnp.ndarray,
    ) -> tuple[BatchedSGDState, jnp.ndarray]:
        certs = jnp.asarray(certs, jnp.float32)
        new = state._replace(
            params=masked_rows(take, models, state.params),
            # restart both the envelope and the estimator at the adopted
            # certificate — acceptance is eps-gated by the engine, so
            # this only ever lowers cert (monotonicity holds)
            cert=jnp.where(take, certs, state.cert),
            est=jnp.where(take, certs, state.est),
        )
        return new, jnp.zeros_like(state.cert)


def lm_sgd_worker(
    arch_cfg: Any,
    opt_cfg: AdamWConfig,
    sgd_cfg: Any,
    batch_size: int = 4,
    seq: int = 64,
) -> BatchedSGDWorker:
    """The concrete instantiation: a ``repro.models`` transformer with
    AdamW on the synthetic token stream. Each worker draws its own
    batches from its state-carried PRNG key, standing in for the
    paper's independent per-machine data shards."""
    from repro.data.tokens import synthetic_token_batch
    from repro.models import init_params, loss_fn

    return BatchedSGDWorker(
        init_fn=lambda key: init_params(arch_cfg, key),
        loss_fn=lambda params, batch: loss_fn(params, arch_cfg, batch),
        batch_fn=lambda key: synthetic_token_batch(key, batch_size, seq, arch_cfg.vocab),
        opt_cfg=opt_cfg,
        sgd_cfg=sgd_cfg,
    )
