"""Shared run bookkeeping for the TMSN execution substrates.

Both the event-driven :class:`~repro.core.simulator.TMSNSimulator`
(fidelity-1 oracle: exact per-event ordering, continuous latencies) and
the vectorized round-based :class:`~repro.core.engine.TMSNEngine`
(fidelity-2: one segment per round, latencies quantized to rounds,
everything batched over the worker axis) produce the same result type,
so benchmark and analysis code is substrate-agnostic.

Sharding contract: everything in this module lives on the HOST after a
run — nothing here is ever traced or sharded. The one shard-aware seam
is :meth:`TrafficCounters.from_shards`: the sharded engines accumulate
``sent`` / ``accepted`` / ``discarded`` / ``sent_dcn`` as per-shard
``(n_devices,)`` partials (a per-round ``psum`` inside the step would
cost a collective per round) and this classmethod is the single place
the cross-shard reduction to global scalars happens. Per-round gossip
footprints in :class:`SimResult` are replicated config-derived figures,
identical on every shard by construction.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import numpy as np


@dataclasses.dataclass
class TrafficCounters:
    """Message accounting shared by the simulator and the engine.

    The engine delivers at most one (the best) message per destination
    per round, so its ``accepted`` counts adoptions while the event
    simulator counts every individually-accepted RECV; the end states
    agree (adopting the min dominates adopting a chain of decreasing
    certificates) but the counters are substrate-level diagnostics, not
    protocol invariants.
    """

    sent: int = 0
    accepted: int = 0
    discarded: int = 0
    bytes_broadcast: int = 0
    #: interconnect-tier split of ``sent`` (pod-mesh engine): pushes
    #: that crossed a pod boundary (DCN); the intra-pod (ICI) half and
    #: the byte figures are derived properties below, so the split can
    #: never drift from the totals. Single-tier substrates report 0.
    sent_dcn: int = 0
    #: sparse-engine capacity evictions: candidates offered to a
    #: bounded pending queue but not retained (0 on the dense oracle
    #: and the event sim; 0 on a sparse run certifies it exact)
    evicted: int = 0
    #: payload size one push carries (kept so the derived byte split
    #: stays consistent with ``bytes_broadcast``)
    payload_bytes: int = 0
    #: cumulative CONTROL-plane bytes over the run (certificates /
    #: broadcast flags / candidate ids, as opposed to model payloads):
    #: the per-round ``control_bytes_per_round`` figure × rounds. 0 on
    #: the event sim and the single-device engine (no wire).
    control_bytes: int = 0
    #: messages removed in flight by FaultPlan injection — random
    #: per-edge drops plus partition-window drops (0 without a plan)
    dropped_injected: int = 0
    #: push candidates rejected by the eps-gate soundness check
    #: (non-finite or non-improving certificates; active only under a
    #: FaultPlan, so 0 on every clean run)
    corrupt_rejected: int = 0

    @property
    def sent_ici(self) -> int:
        return self.sent - self.sent_dcn

    @property
    def bytes_dcn(self) -> int:
        return self.sent_dcn * self.payload_bytes

    @classmethod
    def from_shards(
        cls,
        sent: Any,
        accepted: Any,
        discarded: Any,
        payload_bytes: int,
        sent_dcn: Any = 0,
        evicted: Any = 0,
        control_bytes: int = 0,
        dropped_injected: Any = 0,
        corrupt_rejected: Any = 0,
    ) -> "TrafficCounters":
        """Reduce per-shard partial counters into global totals.

        The sharded engine keeps one partial counter per device (summing
        inside the shard-mapped step would cost a ``psum`` per round);
        the single-device engine passes () scalars. ``np.sum`` handles
        both shapes, so this is the one place the reduction lives —
        including the per-tier ICI/DCN split of the pod-mesh engine
        (``sent`` is the all-tier total; ``sent_dcn`` the pod-crossing
        part; ICI is the difference).
        """
        total = int(np.sum(sent))
        return cls(
            sent=total,
            accepted=int(np.sum(accepted)),
            discarded=int(np.sum(discarded)),
            bytes_broadcast=total * payload_bytes,
            sent_dcn=int(np.sum(sent_dcn)),
            evicted=int(np.sum(evicted)),
            payload_bytes=payload_bytes,
            control_bytes=int(control_bytes),
            dropped_injected=int(np.sum(dropped_injected)),
            corrupt_rejected=int(np.sum(corrupt_rejected)),
        )


@dataclasses.dataclass
class SimResult:
    #: (sim_time, worker_id, certificate) at every local improvement/adopt
    history: list[tuple[float, int, float]]
    final_certificates: list[float]
    final_models: list[Any]
    sim_time: float
    messages_sent: int
    messages_accepted: int
    messages_discarded: int
    bytes_broadcast: int
    cost_units_total: float
    events_processed: int
    #: per-worker wall time spent blocked (always 0 for TMSN — kept so
    #: the BSP baseline harness can report the contrast)
    wait_time: list[float] = dataclasses.field(default_factory=list)
    #: (sim_time, best_certificate, best_model) checkpoints
    snapshots: list = dataclasses.field(default_factory=list)
    #: rounds executed (round-based engine only; 0 for the event sim)
    rounds: int = 0
    #: cross-device gossip exchange footprint per round in bytes —
    #: 0 for the event sim and the single-device engine; the sum of the
    #: ICI and (amortized) DCN tiers below for the sharded engines. For
    #: the single-tier engine the figure is per ``gossip_mode``:
    #:   dense: W · (payload + 4 + 1)            (every model, every round)
    #:   gated: W · 5 + n_dev · k · (payload + 4) (certs/flags densely,
    #:          payloads only for top-k improved candidates per device)
    gossip_bytes_per_round: int = 0
    #: per-tier split on the pod-mesh engine: the intra-pod all_gather
    #: footprint (every round, over the ``workers`` axis — ICI class
    #: links) vs the cross-pod candidate exchange (every
    #: ``cross_pod_every_k`` rounds over the ``pod`` axis — DCN class),
    #: the DCN figure amortized per round. Single-tier substrates
    #: report everything as ICI and 0 DCN.
    gossip_bytes_per_round_ici: int = 0
    gossip_bytes_per_round_dcn: int = 0
    #: pushes that crossed a pod boundary (0 off the pod-mesh engine)
    messages_sent_dcn: int = 0
    #: which gossip policy produced ``gossip_bytes_per_round``
    #: ("dense" | "gated"; single-device substrates report "dense")
    gossip_mode: str = "dense"
    #: sparse-engine capacity evictions over the whole run (0 on the
    #: dense oracle and the event sim). 0 on a sparse run is the
    #: run-level witness that bounded capacity changed nothing — the
    #: run is bit-identical to the dense oracle.
    messages_evicted: int = 0
    #: peak pre-eviction pending-queue occupancy any destination saw
    #: (sparse engine only; the measured capacity floor for an exact
    #: rerun of the same config). 0 on dense/event substrates.
    inflight_occupancy_peak: int = 0
    #: CONTROL-plane share of ``gossip_bytes_per_round`` — the
    #: certificate/flag/id bytes as opposed to model payload bytes:
    #:   dense control: W_tier · 5 per round (f32 cert + bool flag)
    #:   sparse control: n_dev · k · 12 (f32 cert + i32 id + i32 round)
    #: 0 off the sharded engines (no wire).
    control_bytes_per_round: int = 0
    #: which control-plane policy produced the figures above
    #: ("dense" | "sparse")
    control_plane: str = "dense"
    #: the capacity the ``inflight_capacity="auto"`` warm-up probe
    #: selected for this run (0 when capacity was explicit)
    inflight_capacity_selected: int = 0
    #: messages removed in flight by FaultPlan injection (random drops
    #: + partition-window drops; 0 on clean runs and the event sim)
    messages_dropped_injected: int = 0
    #: push candidates rejected by the eps-gate soundness check —
    #: non-finite or non-improving certificates, which a corrupt
    #: message must present to be dangerous (0 on clean runs)
    messages_corrupt_rejected: int = 0
    #: MembershipPlan joins that activated a spare strictly after round
    #: 0 and before the run ended (a join at round 1 is a from-the-start
    #: member and does not count — it is bit-identical to a plain run)
    workers_joined: int = 0

    def best_certificate_trace(self) -> list[tuple[float, float]]:
        """Monotone (time, best-cert-so-far) envelope across workers."""
        out: list[tuple[float, float]] = []
        best = float("inf")
        for t, _, c in sorted(self.history):
            if c < best:
                best = c
                out.append((t, best))
        return out

    @classmethod
    def from_traffic(
        cls,
        traffic: TrafficCounters,
        **kw: Any,
    ) -> "SimResult":
        return cls(
            messages_sent=traffic.sent,
            messages_accepted=traffic.accepted,
            messages_discarded=traffic.discarded,
            bytes_broadcast=traffic.bytes_broadcast,
            messages_sent_dcn=traffic.sent_dcn,
            messages_evicted=traffic.evicted,
            messages_dropped_injected=traffic.dropped_injected,
            messages_corrupt_rejected=traffic.corrupt_rejected,
            **kw,
        )
