"""Early-stopping rules for the Scanner (paper Thm 1 / Algorithm 2).

The scanner accumulates, over the examples it has read so far,

    m[h] = sum_i w_i y_i h(x_i)      (signed weighted edge mass)
    W    = sum_i |w_i|               (total weight scanned)
    V    = sum_i w_i^2               (martingale variance proxy)

and fires on weak rule ``h`` as soon as

    |m[h] - 2*gamma*W| > C * sqrt( V * ( loglog(V/|M|) + log(1/delta) ) )

(Balsubramani 2014, finite-time iterated-logarithm martingale
concentration — paper Theorem 1 and ``StoppingRule`` in Algorithm 2).
A positive sign of ``m - 2*gamma*W`` certifies that the true edge of
``h`` exceeds ``gamma`` w.h.p.; a negative sign certifies ``-h``.

We also provide a plain Hoeffding-style rule for ablations (the rule
used by earlier work, FilterBoost / Domingo-Watanabe style), so the
tightness comparison in EXPERIMENTS.md can quantify why the paper picks
the iterated-logarithm rule.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


class StoppingRuleParams(NamedTuple):
    """Global parameters C and delta of Algorithm 2."""

    C: float = 1.0
    delta: float = 1e-6
    # Numerical floor inside log log; also serves as M_0 in the paper's
    # ``loglog(V/M_0)`` (the pseudocode writes loglog(V/|M|)).
    m0: float = 1.0


def stopping_threshold(V: jnp.ndarray, M: jnp.ndarray, params: StoppingRuleParams) -> jnp.ndarray:
    """RHS of the stopping rule: ``C * sqrt(V * (loglog(V/|M|) + log(1/delta)))``.

    Safe for V = 0 and M = 0 (returns +inf so the rule never fires on no
    evidence).
    """
    V = jnp.asarray(V, dtype=jnp.float32)
    M = jnp.abs(jnp.asarray(M, dtype=jnp.float32))
    ratio = jnp.maximum(V / jnp.maximum(M, params.m0), jnp.e)
    loglog = jnp.log(jnp.log(ratio))
    inner = V * (jnp.maximum(loglog, 0.0) + jnp.log(1.0 / params.delta))
    thr = params.C * jnp.sqrt(jnp.maximum(inner, 0.0))
    return jnp.where(V > 0, thr, jnp.inf)


def stopping_rule_fires(
    m: jnp.ndarray,
    W: jnp.ndarray,
    V: jnp.ndarray,
    gamma: jnp.ndarray | float,
    params: StoppingRuleParams,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Vectorized stopping rule over a batch of candidate weak rules.

    Args:
        m: per-candidate signed edge mass, shape (num_candidates,).
        W: scalar total |w| scanned.
        V: scalar sum of w^2 scanned.
        gamma: target edge.
        params: rule constants.

    Returns:
        (fires, signs, score): boolean per-candidate fire flags, the sign
        (+1/-1) certifying whether h or -h has the edge, and the firing
        margin (statistic minus threshold; larger = stronger evidence).

    Note on the two-sided test: the paper's pseudocode writes
    ``M = |m - 2*gamma*W|`` but a very negative ``m - 2*gamma*W`` only
    certifies that *h is bad*, not that ``-h`` is good. The correct
    statistic for the negated rule is ``(-m) - 2*gamma*W`` (since
    ``m(-h) = -m(h)``); we test both sides properly.
    """
    gw = 2.0 * jnp.asarray(gamma) * W
    Mp = m - gw  # evidence that h has edge > gamma
    Mn = -m - gw  # evidence that -h has edge > gamma
    thr_p = stopping_threshold(V, Mp, params)
    thr_n = stopping_threshold(V, Mn, params)
    fire_p = Mp > thr_p
    fire_n = Mn > thr_n
    fires = fire_p | fire_n
    score_p = Mp - thr_p
    score_n = Mn - thr_n
    use_p = score_p >= score_n
    signs = jnp.where(use_p, 1.0, -1.0).astype(jnp.float32)
    score = jnp.where(use_p, score_p, score_n)
    return fires, signs, score


def hoeffding_threshold(V: jnp.ndarray, t: jnp.ndarray, params: StoppingRuleParams) -> jnp.ndarray:
    """Naive union-bound Hoeffding threshold at a fixed horizon ``t``
    (used only for the tightness ablation): ``sqrt(2 V log(2 t^2/delta))``.

    The ``t^2`` accounts for a union bound over stopping times — this is
    exactly the looseness the iterated-logarithm rule removes.
    """
    V = jnp.asarray(V, dtype=jnp.float32)
    t = jnp.maximum(jnp.asarray(t, dtype=jnp.float32), 1.0)
    thr = jnp.sqrt(2.0 * V * jnp.log(2.0 * t * t / params.delta))
    return jnp.where(V > 0, thr, jnp.inf)


def hoeffding_rule_fires(
    m: jnp.ndarray,
    W: jnp.ndarray,
    V: jnp.ndarray,
    t: jnp.ndarray,
    gamma: jnp.ndarray | float,
    params: StoppingRuleParams,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Hoeffding-with-union-bound variant of :func:`stopping_rule_fires`."""
    gw = 2.0 * jnp.asarray(gamma) * W
    Mp = m - gw
    Mn = -m - gw
    thr = hoeffding_threshold(V, t, params)
    fire_p = Mp > thr
    fire_n = Mn > thr
    fires = fire_p | fire_n
    use_p = Mp >= Mn
    signs = jnp.where(use_p, 1.0, -1.0).astype(jnp.float32)
    return fires, signs
