"""Vectorized round-based TMSN engine (fidelity level 2).

The event-driven :class:`~repro.core.simulator.TMSNSimulator` is the
fidelity-1 oracle: exact per-event ordering, continuous latencies, one
Python heap pop (and one small JAX dispatch) per worker segment. That
is faithful but interpreter-bound — past ~16 workers the wall clock is
all Python, which puts the paper's actual regime (hundreds of machines,
resilience that only shows at scale) out of reach.

This engine trades event fidelity for a *round* abstraction that keeps
every worker on the device at once:

  * all W workers carry their state as stacked ``(W, ...)`` arrays and
    advance one scheduling segment per round inside a single jitted
    computation (``vmap`` over the worker axis);
  * gossip is a masked exchange step — per-link latencies are quantized
    to integer round delays and carried in a ``(W, W, D)`` in-flight
    certificate buffer (``inflight[dst, src, d]`` = certificate of a
    message from ``src`` reaching ``dst`` in ``d`` more rounds), with
    model payloads looked up in a ``(D, W)`` snapshot ring;
  * ``accepts`` / ``improves`` from :mod:`repro.core.protocol` are
    applied elementwise, so fail-stop is a boolean mask and laggards
    are a per-worker speed vector driving a compute-credit accumulator
    (a 0.25-speed worker completes a segment every 4th round).

Round order (matches the event sim under zero latency + uniform speed:
a message broadcast during round ``r`` is applied to every receiver
*before* its round ``r+1`` segment):

  1. deliver arrivals due this round (adopt the best accepted message),
  2. shift the in-flight buffer,
  3. run one segment per live, credit-covered worker (resample-flagged
     workers spend their segment on the batched resample path),
  4. broadcast certificates that strictly improved,
  5. snapshot every worker's model into the ring.

The engine returns the same :class:`~repro.core.result.SimResult` as
the simulator, so benchmarks and analysis are substrate-agnostic.

Dispatch chunking: at small per-round compute the wall clock is one
Python dispatch + one host sync *per round*. The engine therefore runs
:attr:`EngineConfig.rounds_per_dispatch` rounds per jitted call inside
a ``lax.scan``, returning the per-round :class:`RoundInfo` stacked over
the chunk — one dispatch and at most one device sync per chunk, while
per-round history and the *exact* round that crossed
``target_certificate`` are still recovered on the host. When a target
is set, a ``done`` flag inside the scan freezes the carried state on
the crossing round, so the final state is bit-identical to an
unchunked (``rounds_per_dispatch=1``) run for every chunk size.

Fidelity level 3 — the device-sharded substrate: when
:attr:`EngineConfig.mesh` names a multi-device ``workers`` mesh,
:func:`make_engine` returns a
:class:`~repro.core.engine_sharded.ShardedTMSNEngine` that partitions
the stacked ``(W, ...)`` worker state over the mesh with ``shard_map``.
Each device advances only its ``W_local = W / n_dev`` workers per
round; the ``(W, W, D)`` in-flight buffer becomes a per-shard
``(W_local, W, D)`` slice (destination-sharded), and gossip is one
explicit ``all_gather`` of the round's certificates and model payloads
— O(W·payload) traffic per round instead of replicated global state,
or O(n_dev·k·payload) under :attr:`EngineConfig.gossip_mode` "gated",
where only each device's top-k locally-improved candidates ship their
model.
The equivalence contract is strict: on identical configs and seeds the
sharded engine must produce the *same final certificates* as this
single-device engine (which PR 1 in turn pins against the event-driven
fidelity-1 oracle), including fail-stop masks and laggard credit;
``tests/test_sharded_engine.py`` enforces it on 8 forced host devices.

One rung further, a 2-D ``("pod", "workers")`` mesh makes the gossip
hierarchical: per-round all_gathers stay inside a pod (ICI) while only
each device's freshest top-k pending improvements cross the ``pod``
axis (DCN) every :attr:`EngineConfig.cross_pod_every_k` rounds —
bit-identical to the flat engine at ``k=1`` under uniform delay, a
benchmark-measured approximation beyond.

Sharding contract: everything in this module is written to be
shardable over the worker axis — every per-worker quantity (including
per-worker constants like feature-ownership masks) lives in the state
pytree with a leading ``(W,)`` axis and shards with it; scalars carried
in :class:`EngineState` (``round``, the counters on THIS engine) are
replicated. On the single-device engine the distinction is vacuous;
:mod:`repro.core.engine_sharded` states the full per-shard/replicated
split its ``shard_map`` enforces.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, NamedTuple, Protocol

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import accepts, improves
from repro.core.result import SimResult, TrafficCounters


def _env_int(name: str, default: int) -> int:
    """Integer ``REPRO_*`` override: unset/empty/whitespace falls back
    to the default; a malformed value raises naming the variable (the
    bare ``int()`` error would not say where the bad string came from)."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return int(raw)
    except ValueError:
        raise ValueError(f"env override {name} must be an integer, got {raw!r}") from None


def _env_str(name: str, default: str) -> str:
    """String ``REPRO_*`` override; unset/empty/whitespace = default.
    Value validation stays with the consumer (TMSNEngine rejects unknown
    gossip modes whether they came from the env or an explicit arg)."""
    raw = os.environ.get(name, "").strip()
    return raw if raw else default


class BatchedTMSNWorker(Protocol):
    """Duck-typed batched worker plugged into the engine.

    All methods must be pure and traceable (the engine jits the whole
    round step, worker computation included). States are stacked
    pytrees with a leading worker axis; certificates are ``(W,)``
    float32 arrays (lower = better).

    Certificates must be monotone non-increasing over rounds (a scan
    may only keep or lower a worker's certificate, and adoption is
    accept-gated so it only lowers it). The protocol itself only
    compares instantaneous values, but the sharded engine's gated
    gossip mode leans on monotonicity for its gated==dense equivalence
    under uniform delay — see :mod:`repro.core.engine_sharded`.
    """

    def init_batch(self, n_workers: int, seed: int) -> Any: ...

    def scan_round(self, state: Any, mask: jnp.ndarray) -> tuple[Any, jnp.ndarray, jnp.ndarray]:
        """Run one segment for every worker where ``mask`` is True.

        Returns (new_state, cost (W,), fired (W,)); masked-out workers
        must come back unchanged with zero cost.
        """
        ...

    def needs_resample(self, state: Any) -> jnp.ndarray:
        """(W,) bool — workers whose next segment is a resample (may be
        all-False forever for workers without a sampling phase)."""
        ...

    def resample_round(self, state: Any, do: jnp.ndarray) -> tuple[Any, jnp.ndarray]:
        """Spend the segment of every worker where ``do`` on a resample;
        returns (new_state, cost (W,))."""
        ...

    def certificates(self, state: Any) -> jnp.ndarray: ...

    def export_models(self, state: Any) -> Any:
        """Stacked model pytree with leading worker axis (the broadcast
        payload; must be cheap — no recomputation).

        Workers may additionally implement the optional
        ``export_payload_rows(state, rows) -> models`` hook: gather just
        ``rows`` (a (k,) int array of worker-axis indices) of the
        payload. The sharded engine's candidate-selecting tiers use it
        — gated gossip ships only the top-k locally-improved candidate
        models instead of the full stack, and the pod-mesh cross-pod
        tier ships the top-k pending candidates per flush; absent the
        hook both fall back to indexing ``export_models``."""
        ...

    def adopt_batch(
        self, state: Any, models: Any, certs: jnp.ndarray, take: jnp.ndarray
    ) -> tuple[Any, jnp.ndarray]:
        """Adopt ``models[i]``/``certs[i]`` wherever ``take[i]``;
        returns (new_state, cost (W,))."""
        ...

    def payload_bytes(self) -> int: ...


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_workers: int = 4
    eps: float = 0.0  # protocol gap; gates ACCEPTANCE only (as in the sim)
    max_rounds: int = 1000
    #: per-link broadcast latency in ROUNDS: an int (uniform) or a
    #: (W, W) ``delay[src, dst]`` integer array, clipped to >= 1. A
    #: message sent during round r is delivered at round r + delay.
    delay_rounds: Any = 1
    #: per-worker speed, cost units per simulated second; also drives
    #: the round-level compute credit (normalized to the fastest
    #: worker). None = uniform.
    speed: Any = None
    #: round index at which each worker fail-stops (None = never).
    fail_round: Any = None
    target_certificate: float | None = None
    seed: int = 0
    #: record per-worker certificate changes into SimResult.history
    record_history: bool = True
    #: rounds advanced per jitted dispatch (``lax.scan`` chunk). 1 =
    #: the old one-dispatch-per-round behavior; larger chunks amortize
    #: Python dispatch + host sync without changing any protocol
    #: semantics (exact rounds-to-target and per-round history are
    #: recovered from the stacked per-round info). Env-overridable so
    #: CI can rerun the whole tier chunked: REPRO_ROUNDS_PER_DISPATCH.
    rounds_per_dispatch: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_ROUNDS_PER_DISPATCH", 8)
    )
    #: cross-device gossip policy of the SHARDED engine (ignored on one
    #: device). "dense": all_gather every worker's model payload every
    #: round — O(W·payload) on the wire. "gated": all_gather only the
    #: cheap certificates + broadcast flags (W·5 bytes) densely; model
    #: payloads move only for each device's top-``gossip_top_k``
    #: locally-improved candidates — O(n_dev·k·payload). The eps gate
    #: still applies to ACCEPTANCE only; gating shapes traffic via the
    #: improvement test. Under uniform delay gated mode adopts models
    #: identical to dense mode (the per-round argmin is always among
    #: per-shard minima — pinned in tests/test_sharded_engine.py);
    #: under heterogeneous delay matrices it is an explicit
    #: approximation. Env-overridable: REPRO_GOSSIP_MODE.
    gossip_mode: str = dataclasses.field(
        default_factory=lambda: _env_str("REPRO_GOSSIP_MODE", "dense")
    )
    #: per-device candidate count for gated gossip (clamped to the
    #: shard's local worker count)
    gossip_top_k: int = 1
    #: cross-pod exchange cadence of the pod-mesh engine, in rounds
    #: (ignored without a ``pod`` mesh axis). 1 = flush the cross-pod
    #: tier every round, which under UNIFORM delay reproduces the flat
    #: single-axis engine bit-identically (pinned in
    #: tests/test_sharded_engine.py); k > 1 lets improvements accumulate
    #: in the pending tier and ships only the freshest certificates
    #: every k-th round over the DCN — an explicit approximation,
    #: measured by bench_scaling.py. Env: REPRO_CROSS_POD_EVERY_K.
    cross_pod_every_k: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_CROSS_POD_EVERY_K", 1)
    )
    #: per-device candidate count for each cross-pod flush (the PR 3
    #: top-k gated payload path applied to the pod axis; clamped to the
    #: shard's local worker count). Env: REPRO_CROSS_POD_TOP_K.
    cross_pod_top_k: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_CROSS_POD_TOP_K", 1)
    )
    #: optional ``jax.sharding.Mesh``: a 1-D ``("workers",)`` mesh
    #: shards the worker axis over one interconnect tier; a 2-D
    #: ``("pod", "workers")`` mesh adds the hierarchical cross-pod tier
    #: (``launch/mesh.py::make_worker_mesh(pods=...)`` builds both).
    #: ``None`` or a 1-device mesh keeps the single-device path; a
    #: multi-device mesh makes :func:`make_engine` build the
    #: shard-mapped engine (``n_workers`` must divide evenly over the
    #: total device count).
    mesh: Any = None


class EngineState(NamedTuple):
    worker: Any
    certs: jnp.ndarray  # (W,) f32 — post-round certificates, carried so
    # the next round's acceptance test needs no third certificates() call
    alive: jnp.ndarray  # (W,) bool
    credit: jnp.ndarray  # (W,) f32 compute credit (laggard model)
    clock: jnp.ndarray  # (W,) f32 per-worker simulated seconds
    inflight: jnp.ndarray  # (W, W, D) f32 — [dst, src, d] certs; +inf = empty
    ring: Any  # model snapshots, leading (D, W) — (n_pods*D, W) on a pod mesh
    round: jnp.ndarray  # () i32
    sent: jnp.ndarray  # () i32
    accepted: jnp.ndarray  # () i32
    discarded: jnp.ndarray  # () i32
    cost_total: jnp.ndarray  # () f32
    #: (W,) bool — cross-pod tier: workers whose improvement is pending
    #: the next pod-axis flush (constant False off the pod-mesh engine)
    xpend: jnp.ndarray
    #: () i32 — pushes that crossed a pod boundary (DCN tier); a
    #: (n_dev,) per-shard partial on the sharded engines, like `sent`
    sent_dcn: jnp.ndarray


class RoundInfo(NamedTuple):
    """Small per-round summary fetched to the host for history/stop."""

    certs: jnp.ndarray  # (W,)
    changed: jnp.ndarray  # (W,) bool — cert changed this round (fire or adopt)
    clock: jnp.ndarray  # (W,)
    alive: jnp.ndarray  # (W,)


def _tree_stack_rows(tree: Any, depth: int) -> Any:
    """Tile a stacked (W, ...) pytree into a (D, W, ...) ring."""
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (depth,) + a.shape).copy(), tree
    )


class TMSNEngine:
    """Round-based TMSN run over a batched worker."""

    def __init__(self, worker: BatchedTMSNWorker, config: EngineConfig) -> None:
        self.worker = worker
        self.config = config
        w = config.n_workers

        if config.gossip_mode not in ("dense", "gated"):
            raise ValueError(
                f"gossip_mode must be 'dense' or 'gated', got {config.gossip_mode!r}"
            )
        if config.gossip_top_k < 1:
            raise ValueError(f"gossip_top_k must be >= 1, got {config.gossip_top_k}")
        if config.rounds_per_dispatch < 1:
            raise ValueError(
                f"rounds_per_dispatch must be >= 1, got {config.rounds_per_dispatch}"
            )
        if config.cross_pod_every_k < 1:
            raise ValueError(
                f"cross_pod_every_k must be >= 1, got {config.cross_pod_every_k}"
            )
        if config.cross_pod_top_k < 1:
            raise ValueError(
                f"cross_pod_top_k must be >= 1, got {config.cross_pod_top_k}"
            )

        delay = np.asarray(config.delay_rounds)
        if delay.ndim == 0:
            delay = np.full((w, w), int(delay))
        if delay.shape != (w, w):
            raise ValueError(f"delay_rounds must be scalar or ({w},{w}), got {delay.shape}")
        self._delay = jnp.asarray(np.maximum(delay, 1), jnp.int32)
        self._depth = int(np.maximum(delay, 1).max())

        speed = np.ones(w) if config.speed is None else np.asarray(config.speed, np.float64)
        if speed.shape != (w,):
            raise ValueError(f"speed must be ({w},), got {speed.shape}")
        self._speed = jnp.asarray(speed, jnp.float32)
        self._speed_norm = jnp.asarray(speed / speed.max(), jnp.float32)

        fail = (
            np.full(w, np.iinfo(np.int32).max)
            if config.fail_round is None
            else np.asarray(config.fail_round)
        )
        if fail.shape != (w,):
            raise ValueError(f"fail_round must be ({w},), got {fail.shape}")
        self._fail_round = jnp.asarray(fail, jnp.int32)

        #: compiled chunk dispatchers keyed by scan length (the main
        #: chunk size plus at most one remainder length per run)
        self._chunks: dict[int, Any] = {}

    # ------------------------------------------------------------------
    # dispatch chunking: K rounds per jitted call via lax.scan
    # ------------------------------------------------------------------
    def _chunk_body(self, step, any_reduce):
        """Scan body ``(state, done), _ -> ((state, done), RoundInfo)``.

        ``step`` is the (possibly shard-mapped) single-round step;
        ``any_reduce`` turns a (local) boolean vector into a scalar
        "any worker, any shard" — ``jnp.any`` on one device, a psum on
        the sharded engine. When ``target_certificate`` is set, ``done``
        freezes the carried state on the crossing round so the final
        state is identical to an unchunked run for every chunk size.
        """
        target = self.config.target_certificate

        def frozen(state):
            # post-crossing rounds: state passes through untouched and
            # the round reports no changes (so host history/stop logic
            # sees the crossing round as the last live one)
            info = RoundInfo(
                certs=state.certs,
                changed=jnp.zeros_like(state.alive),
                clock=state.clock,
                alive=state.alive,
            )
            return state, info

        def body(carry, _):
            state, done = carry
            if target is None:
                new_state, info = step(state)
            else:
                # cond, not select: once done, the remaining rounds of
                # the chunk skip the whole step (worker scan, gossip
                # collectives, ring writes) instead of computing and
                # discarding it. `done` derives from an all-shard
                # reduction, so every device takes the same branch and
                # the collectives inside stay uniform.
                new_state, info = jax.lax.cond(done, frozen, step, state)
                done = done | any_reduce(info.alive & (info.certs <= target))
            return (new_state, done), info

        return body

    def _build_chunk(self, length: int):
        """Jitted ``state -> (state, RoundInfo stacked over length)``;
        the sharded engine overrides this to run the scan inside
        ``shard_map``."""
        body = self._chunk_body(self._round_step, jnp.any)

        def chunk(state: EngineState):
            (state, _), infos = jax.lax.scan(
                body, (state, jnp.zeros((), bool)), None, length=length
            )
            return state, infos

        return jax.jit(chunk)

    def _chunk_fn(self, length: int):
        fn = self._chunks.get(length)
        if fn is None:
            fn = self._chunks[length] = self._build_chunk(length)
        return fn

    # ------------------------------------------------------------------
    def _init_state(self) -> EngineState:
        cfg = self.config
        w, d = cfg.n_workers, self._depth
        wstate = self.worker.init_batch(w, cfg.seed)
        models = self.worker.export_models(wstate)
        return EngineState(
            worker=wstate,
            certs=jnp.asarray(self.worker.certificates(wstate), jnp.float32),
            alive=jnp.ones((w,), bool),
            credit=jnp.zeros((w,), jnp.float32),
            clock=jnp.zeros((w,), jnp.float32),
            inflight=jnp.full((w, w, d), jnp.inf, jnp.float32),
            ring=_tree_stack_rows(models, d),
            round=jnp.zeros((), jnp.int32),
            sent=jnp.zeros((), jnp.int32),
            accepted=jnp.zeros((), jnp.int32),
            discarded=jnp.zeros((), jnp.int32),
            cost_total=jnp.zeros((), jnp.float32),
            xpend=jnp.zeros((w,), bool),
            sent_dcn=jnp.zeros((), jnp.int32),
        )

    def _round_step(self, state: EngineState) -> tuple[EngineState, RoundInfo]:
        cfg = self.config
        w, depth = cfg.n_workers, self._depth
        r = state.round
        dst_idx = jnp.arange(w)
        alive = state.alive & (r < self._fail_round)

        # last round's post-scan certificates, carried in the state (no
        # third certificates() call per round)
        certs0 = state.certs

        # --- 1. deliver arrivals due this round ---------------------------
        arr = state.inflight[:, :, 0]  # (dst, src) certs
        arr_live = jnp.where(alive[:, None], arr, jnp.inf)
        best_src = jnp.argmin(arr_live, axis=1)  # (W,)
        best_cert = arr_live[dst_idx, best_src]
        take = accepts(certs0, best_cert, cfg.eps) & jnp.isfinite(best_cert)
        n_arrivals = jnp.sum(jnp.isfinite(arr), dtype=jnp.int32)
        n_taken = jnp.sum(take, dtype=jnp.int32)

        sent_slot = (r - self._delay[best_src, dst_idx]) % depth
        in_models = jax.tree_util.tree_map(
            lambda a: a[sent_slot, best_src], state.ring
        )

        def _adopt(operand):
            wstate, models, c, t = operand
            return self.worker.adopt_batch(wstate, models, c, t)

        wstate, adopt_cost = jax.lax.cond(
            jnp.any(take),
            _adopt,
            lambda operand: (operand[0], jnp.zeros((w,), jnp.float32)),
            (state.worker, in_models, best_cert, take),
        )

        # --- 2. shift the in-flight buffer --------------------------------
        inflight = jnp.concatenate(
            [state.inflight[:, :, 1:], jnp.full((w, w, 1), jnp.inf, jnp.float32)], axis=2
        )

        # --- 3. one segment per live, credit-covered worker ---------------
        credit = state.credit + self._speed_norm
        active = alive & (credit >= 1.0 - 1e-6)
        credit = jnp.where(active, credit - 1.0, credit)

        need = self.worker.needs_resample(wstate) & active
        wstate, resample_cost = jax.lax.cond(
            jnp.any(need),
            lambda op: self.worker.resample_round(op[0], op[1]),
            lambda op: (op[0], jnp.zeros((w,), jnp.float32)),
            (wstate, need),
        )
        scan_mask = active & ~need
        certs_pre = self.worker.certificates(wstate)
        wstate, scan_cost, fired = self.worker.scan_round(wstate, scan_mask)
        certs = self.worker.certificates(wstate)

        cost = adopt_cost + resample_cost + scan_cost
        clock = state.clock + cost / jnp.maximum(self._speed, 1e-12)

        # --- 4. broadcast strict improvements -----------------------------
        # (eps gates acceptance only — see the note in simulator.run)
        improved = fired & improves(certs_pre, certs, 0.0) & scan_mask
        d_idx = jnp.arange(depth)[None, None, :]
        # push_mask[dst, src, d] — delay is indexed [src, dst]
        push_mask = (
            improved[None, :, None]
            & alive[:, None, None]
            & (dst_idx[:, None] != dst_idx[None, :])[:, :, None]
            & (d_idx == (self._delay.T[:, :, None] - 1))
        )
        inflight = jnp.where(push_mask, certs[None, :, None], inflight)
        n_pushed = jnp.sum(push_mask, dtype=jnp.int32)

        # --- 5. snapshot the models into the ring -------------------------
        # gated to broadcasters: ring[slot, src] is only ever read for a
        # message src pushed at that slot's round, so non-improved
        # workers keep their (dead) old entry instead of paying a write
        models = self.worker.export_models(wstate)
        ring = jax.tree_util.tree_map(
            lambda buf, m: buf.at[r % depth].set(
                jnp.where(
                    improved.reshape((-1,) + (1,) * (m.ndim - 1)), m, buf[r % depth]
                )
            ),
            state.ring,
            models,
        )

        new_state = EngineState(
            worker=wstate,
            certs=certs,
            alive=alive,
            credit=credit,
            clock=clock,
            inflight=inflight,
            ring=ring,
            round=r + 1,
            sent=state.sent + n_pushed,
            accepted=state.accepted + n_taken,
            discarded=state.discarded + (n_arrivals - n_taken),
            cost_total=state.cost_total + jnp.sum(cost),
            xpend=state.xpend,
            sent_dcn=state.sent_dcn,
        )
        info = RoundInfo(
            certs=certs, changed=take | improved, clock=clock, alive=alive
        )
        return new_state, info

    # ------------------------------------------------------------------
    def run(self) -> SimResult:
        cfg = self.config
        state = self._init_state()
        certs0 = np.asarray(state.certs)
        history: list[tuple[float, int, float]] = [
            (0.0, i, float(certs0[i])) for i in range(cfg.n_workers)
        ]

        rounds = 0
        # only fetch per-chunk info to the host when something consumes
        # it — a fixed-round throughput run stays free of device syncs
        # so JAX can queue whole chunks asynchronously
        fetch = cfg.record_history or cfg.target_certificate is not None
        k = int(cfg.rounds_per_dispatch)  # validated >= 1 in __init__
        remaining = int(cfg.max_rounds)
        while remaining > 0:
            kk = min(k, remaining)
            state, infos = self._chunk_fn(kk)(state)
            remaining -= kk
            if not fetch:
                rounds += kk
                continue
            certs_k = np.asarray(infos.certs)  # (kk, W)
            stop = None
            if cfg.target_certificate is not None:
                # f32 target, matching the in-scan freeze comparison —
                # a float64 host compare could disagree with the device
                # in the ULP window around a non-f32-representable target
                hit = np.any(
                    (certs_k <= np.float32(cfg.target_certificate))
                    & np.asarray(infos.alive),
                    axis=1,
                )
                if hit.any():
                    stop = int(np.argmax(hit))
            last = kk - 1 if stop is None else stop
            rounds += last + 1
            if cfg.record_history:
                # bulk append over the stacked chunk: row-major nonzero
                # keeps (round, worker) order identical to the old
                # per-round per-worker Python loop
                changed_k = np.asarray(infos.changed)
                clock_k = np.asarray(infos.clock)
                rr, ww = np.nonzero(changed_k[: last + 1])
                history.extend(
                    zip(clock_k[rr, ww].tolist(), ww.tolist(), certs_k[rr, ww].tolist())
                )
            if stop is not None:
                break

        certs = np.asarray(state.certs)
        models = self.worker.export_models(state.worker)
        # counters are () scalars on the single-device engine and
        # (n_devices,) per-shard partials on the sharded one; np.sum
        # covers both (the per-shard reduction happens here, once)
        traffic = TrafficCounters.from_shards(
            sent=np.asarray(state.sent),
            accepted=np.asarray(state.accepted),
            discarded=np.asarray(state.discarded),
            payload_bytes=self.worker.payload_bytes(),
            sent_dcn=np.asarray(state.sent_dcn),
        )
        final_models = [
            jax.tree_util.tree_map(lambda a, i=i: a[i], models)
            for i in range(cfg.n_workers)
        ]
        ici_bytes, dcn_bytes = self._gossip_split()
        return SimResult.from_traffic(
            traffic,
            history=history,
            final_certificates=[float(c) for c in certs],
            final_models=final_models,
            sim_time=float(np.asarray(state.clock).max()),
            cost_units_total=float(np.sum(np.asarray(state.cost_total))),
            events_processed=rounds * cfg.n_workers,
            rounds=rounds,
            gossip_bytes_per_round=ici_bytes + dcn_bytes,
            gossip_bytes_per_round_ici=ici_bytes,
            gossip_bytes_per_round_dcn=dcn_bytes,
            gossip_mode=self._gossip_mode(),
        )

    def _gossip_split(self) -> tuple[int, int]:
        """(ICI, DCN) cross-device exchange footprint per round; the DCN
        leg is amortized over ``cross_pod_every_k``. (0, 0) on one
        device."""
        return 0, 0

    def _gossip_mode(self) -> str:
        """Mode label for SimResult; one device has no cross-device
        gossip, so the config knob is reported as inert."""
        return "dense"


def quantize_latency(
    base_latency: float,
    jitter: float,
    round_dt: float,
    n_workers: int,
    seed: int = 0,
) -> np.ndarray:
    """Quantize the simulator's continuous per-link latency model to an
    integer (W, W) round-delay matrix: ``delay = max(1, round(lat/dt))``.

    Jitter is drawn from the same U[0, jitter) distribution as the event
    sim, but sampled ONCE per link and frozen for the whole run (the
    engine's delay matrix is static), whereas the simulator redraws it
    per message — expect distributional differences under jitter > 0."""
    rng = np.random.default_rng(seed)
    lat = base_latency + rng.uniform(0.0, max(jitter, 0.0), size=(n_workers, n_workers))
    dt = max(round_dt, 1e-12)
    return np.maximum(np.rint(lat / dt), 1).astype(np.int32)


def make_engine(worker: BatchedTMSNWorker, config: EngineConfig) -> TMSNEngine:
    """Build the right engine for ``config.mesh``.

    ``mesh=None`` or a 1-device mesh falls back to the single-device
    :class:`TMSNEngine` (the sharded path would only add collective
    overhead); a multi-device mesh with a ``workers`` axis builds the
    shard-mapped :class:`~repro.core.engine_sharded.ShardedTMSNEngine` —
    single-tier on a ``("workers",)`` mesh, hierarchical two-tier on a
    ``("pod", "workers")`` mesh.
    """
    mesh = config.mesh
    if mesh is None or mesh.size == 1:
        return TMSNEngine(worker, config)
    if "workers" not in mesh.axis_names:
        raise ValueError(f"engine mesh needs a 'workers' axis, got {mesh.axis_names}")
    from repro.core.engine_sharded import ShardedTMSNEngine

    return ShardedTMSNEngine(worker, config)
