"""Vectorized round-based TMSN engine (fidelity level 2).

The event-driven :class:`~repro.core.simulator.TMSNSimulator` is the
fidelity-1 oracle: exact per-event ordering, continuous latencies, one
Python heap pop (and one small JAX dispatch) per worker segment. That
is faithful but interpreter-bound — past ~16 workers the wall clock is
all Python, which puts the paper's actual regime (hundreds of machines,
resilience that only shows at scale) out of reach.

This engine trades event fidelity for a *round* abstraction that keeps
every worker on the device at once:

  * all W workers carry their state as stacked ``(W, ...)`` arrays and
    advance one scheduling segment per round inside a single jitted
    computation (``vmap`` over the worker axis);
  * gossip is a masked exchange step — per-link latencies are quantized
    to integer round delays and carried in a ``(W, W, D)`` in-flight
    certificate buffer (``inflight[dst, src, d]`` = certificate of a
    message from ``src`` reaching ``dst`` in ``d`` more rounds), with
    model payloads looked up in a ``(D, W)`` snapshot ring;
  * ``accepts`` / ``improves`` from :mod:`repro.core.protocol` are
    applied elementwise, so fail-stop is a boolean mask and laggards
    are a per-worker speed vector driving a compute-credit accumulator
    (a 0.25-speed worker completes a segment every 4th round).

Round order (matches the event sim under zero latency + uniform speed:
a message broadcast during round ``r`` is applied to every receiver
*before* its round ``r+1`` segment):

  1. deliver arrivals due this round (adopt the best accepted message),
  2. shift the in-flight buffer,
  3. run one segment per live, credit-covered worker (resample-flagged
     workers spend their segment on the batched resample path),
  4. broadcast certificates that strictly improved,
  5. snapshot every worker's model into the ring.

The engine returns the same :class:`~repro.core.result.SimResult` as
the simulator, so benchmarks and analysis are substrate-agnostic.

Dispatch chunking: at small per-round compute the wall clock is one
Python dispatch + one host sync *per round*. The engine therefore runs
:attr:`EngineConfig.rounds_per_dispatch` rounds per jitted call inside
a ``lax.scan``, returning the per-round :class:`RoundInfo` stacked over
the chunk — one dispatch and at most one device sync per chunk, while
per-round history and the *exact* round that crossed
``target_certificate`` are still recovered on the host. When a target
is set, a ``done`` flag inside the scan freezes the carried state on
the crossing round, so the final state is bit-identical to an
unchunked (``rounds_per_dispatch=1``) run for every chunk size.

Fidelity level 3 — the device-sharded substrate: when
:attr:`EngineConfig.mesh` names a multi-device ``workers`` mesh,
:func:`make_engine` returns a
:class:`~repro.core.engine_sharded.ShardedTMSNEngine` that partitions
the stacked ``(W, ...)`` worker state over the mesh with ``shard_map``.
Each device advances only its ``W_local = W / n_dev`` workers per
round; the ``(W, W, D)`` in-flight buffer becomes a per-shard
``(W_local, W, D)`` slice (destination-sharded), and gossip is one
explicit ``all_gather`` of the round's certificates and model payloads
— O(W·payload) traffic per round instead of replicated global state,
or O(n_dev·k·payload) under :attr:`EngineConfig.gossip_mode` "gated",
where only each device's top-k locally-improved candidates ship their
model. :attr:`EngineConfig.control_plane` "sparse" applies the same
idea to the control plane itself: instead of the dense per-round (W,)
certificate + flag all_gather, the exchange carries only (cert,
global_id, round) triples for those top-k candidates — a fixed-size
(n_dev, k) gather scattered into the in-flight state by global id, so
per-round gossip cost is O(n_dev·k), independent of W.
The equivalence contract is strict: on identical configs and seeds the
sharded engine must produce the *same final certificates* as this
single-device engine (which PR 1 in turn pins against the event-driven
fidelity-1 oracle), including fail-stop masks and laggard credit;
``tests/test_sharded_engine.py`` enforces it on 8 forced host devices.

One rung further, a 2-D ``("pod", "workers")`` mesh makes the gossip
hierarchical: per-round all_gathers stay inside a pod (ICI) while only
each device's freshest top-k pending improvements cross the ``pod``
axis (DCN) every :attr:`EngineConfig.cross_pod_every_k` rounds —
bit-identical to the flat engine at ``k=1`` under uniform delay, a
benchmark-measured approximation beyond.

The worker contract this engine drives —
:class:`repro.core.worker.BatchedTMSNWorker` — lives in
:mod:`repro.core.worker` (imported here for backward compatibility);
this module only *consumes* it, through the optional-hook helpers in
that module, and never references any concrete worker type.

Sharding contract: everything in this module is written to be
shardable over the worker axis — every per-worker quantity (including
per-worker constants like feature-ownership masks) lives in the state
pytree with a leading ``(W,)`` axis and shards with it; scalars carried
in :class:`EngineState` (``round``, the counters on THIS engine) are
replicated. On the single-device engine the distinction is vacuous;
:mod:`repro.core.engine_sharded` states the full per-shard/replicated
split its ``shard_map`` enforces.
"""

from __future__ import annotations

import dataclasses
import math
import os
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.protocol import accepts, improves
from repro.core.result import SimResult, TrafficCounters
from repro.core.worker import (
    BatchedTMSNWorker,
    has_resample_hooks,
    resolve_payload_bytes,
)

#: multiplier applied to the warm-up probe's measured
#: ``inflight_occupancy_peak`` when ``inflight_capacity="auto"`` sizes
#: the pending queues — headroom for occupancy growth past the probe
#: window (e.g. laggards catching up, delay tails filling in)
AUTO_CAPACITY_HEADROOM = 2.0


def _env_int(name: str, default: int, special: tuple[str, ...] = ()) -> int | str:
    """Integer ``REPRO_*`` override: unset/empty/whitespace falls back
    to the default; a malformed value raises naming the variable (the
    bare ``int()`` error would not say where the bad string came from).
    ``special`` whitelists non-integer sentinel values (e.g. ``"auto"``
    for REPRO_INFLIGHT_CAPACITY) that pass through verbatim."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    if raw.lower() in special:
        return raw.lower()
    try:
        return int(raw)
    except ValueError:
        raise ValueError(f"env override {name} must be an integer, got {raw!r}") from None


def _env_str(name: str, default: str) -> str:
    """String ``REPRO_*`` override; unset/empty/whitespace = default.
    Value validation stays with the consumer (TMSNEngine rejects unknown
    gossip modes whether they came from the env or an explicit arg)."""
    raw = os.environ.get(name, "").strip()
    return raw if raw else default


def _env_float(name: str, default: float) -> float:
    """Float ``REPRO_*`` override: unset/empty/whitespace falls back to
    the default; a malformed value raises naming the variable (same
    contract as :func:`_env_int`)."""
    raw = os.environ.get(name, "").strip()
    if not raw:
        return default
    try:
        return float(raw)
    except ValueError:
        raise ValueError(f"env override {name} must be a float, got {raw!r}") from None


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """Adversarial message-fault schedule, applied at the gossip
    boundary (the push side of the in-flight state) inside the jitted
    round step of both engines.

    Every mask is drawn from a counter-based hash of ``(round, dst gid,
    src gid, seed, salt)`` — no carried PRNG state, so the same plan
    produces bit-identical faults on every substrate and sharding
    (single-device, sharded, pod mesh), which is what lets
    ``tests/test_chaos.py`` pin cross-substrate equivalence *under*
    faults. Probabilities are per directed edge per round.

    Exact-vs-measured status per field (see docs/architecture.md for
    the arguments): ``drop_prob``/``duplicate_prob`` are EXACT no-ops on
    the final certificates under uniform delay (given adequate queue
    capacity); ``corrupt_prob`` is EXACT (every corrupt certificate is
    rejected by the eps-gate soundness check); ``reorder_max`` and the
    partition window are MEASURED approximations (bench_scaling.py
    chaos section)."""

    #: per-edge probability a pushed message is silently lost
    drop_prob: float = 0.0
    #: per-edge probability a pushed message is enqueued twice
    #: (idempotent no-op on the dense (W, W, D) buffer — same cell
    #: written twice — so only the queue paths see extra entries)
    duplicate_prob: float = 0.0
    #: bounded reorder: delivery round jittered by +U{0..reorder_max},
    #: clamped to push_round + ring depth so the payload snapshot is
    #: still live at delivery. Queue-only (the dense buffer derives the
    #: ring slot from the static delay matrix, so late delivery would
    #: fetch a wrong-generation payload) — the engine rejects
    #: ``reorder_max > 0`` with ``inflight_capacity == 0``.
    reorder_max: int = 0
    #: per-edge probability the pushed certificate is corrupted
    #: (rotating NaN / -inf / +1e6 by hash) — always caught by the
    #: soundness check, accounted in ``messages_corrupt_rejected``
    corrupt_prob: float = 0.0
    seed: int = 0
    #: DCN pod partition: drop EVERY cross-pod edge for rounds in
    #: ``[partition_start, partition_stop)``. Inert off the pod mesh
    #: (no pod geometry => no cross-pod edges). -1/-1 = disabled.
    partition_start: int = -1
    partition_stop: int = -1

    @property
    def active(self) -> bool:
        return (
            self.drop_prob > 0.0
            or self.duplicate_prob > 0.0
            or self.reorder_max > 0
            or self.corrupt_prob > 0.0
            or (0 <= self.partition_start < self.partition_stop)
        )


@dataclasses.dataclass(frozen=True)
class MembershipPlan:
    """Elastic-membership schedule: mid-run joins into pre-allocated
    spare slots, plus leaves (folded into the fail-stop mask).

    ``joins`` holds ``(round, slot)`` pairs with 1-BASED rounds: a join
    at round ``k`` makes the spare's first live round the k-th round of
    the run, so ``k=1`` is provably bit-identical to a run where that
    worker was simply never masked out (the exact pin in
    tests/test_chaos.py). Slots must lie in the spare region
    ``[n_workers - spare_slots, n_workers)`` — spares are allocated (and
    compiled) up front, so activation never recompiles. On activation
    the spare's laggard credit is reseeded to zero (its credit
    accumulator ran while masked) and its batch-stream PRNG key is its
    untouched ``init_batch`` stream (masked rows are bitwise unchanged
    by the worker contract); it adopts the current best certificate
    through the ordinary gossip/accept machinery on its first arrival.

    ``leaves`` holds ``(round, worker)`` pairs, folded into
    ``fail_round`` via min — join + leave composes into churn traces."""

    joins: tuple = ()
    leaves: tuple = ()


def _parse_fault_spec(spec: str) -> FaultPlan | None:
    """Parse the ``REPRO_FAULT_PLAN`` spec string, e.g.
    ``"drop=5,dup=2,corrupt=2,reorder=1,seed=9,part=8:16"`` —
    probabilities in integer PERCENT, ``part`` a ``start:stop`` round
    window. Empty/whitespace = no plan. Malformed values raise naming
    the variable (same contract as ``_env_int``)."""
    spec = spec.strip()
    if not spec:
        return None
    kw: dict[str, Any] = {}
    for field in spec.split(","):
        field = field.strip()
        if not field:
            continue
        key, sep, val = field.partition("=")
        key, val = key.strip().lower(), val.strip()
        if not sep:
            raise ValueError(
                f"env override REPRO_FAULT_PLAN: expected key=value, got {field!r}"
            )
        try:
            if key in ("drop", "dup", "corrupt"):
                pct = int(val)
                if not 0 <= pct <= 100:
                    raise ValueError(
                        f"env override REPRO_FAULT_PLAN: field {key!r} is a "
                        f"percentage and must be in [0, 100], got {pct}"
                    )
                dest = {"drop": "drop_prob", "dup": "duplicate_prob",
                        "corrupt": "corrupt_prob"}[key]
                kw[dest] = pct / 100.0
            elif key == "reorder":
                kw["reorder_max"] = int(val)
            elif key == "seed":
                kw["seed"] = int(val)
            elif key == "part":
                a, _, b = val.partition(":")
                kw["partition_start"] = int(a)
                kw["partition_stop"] = int(b)
            else:
                raise ValueError(
                    f"env override REPRO_FAULT_PLAN: unknown field {key!r} "
                    f"(known: drop, dup, corrupt, reorder, seed, part)"
                )
        except ValueError as e:
            if "REPRO_FAULT_PLAN" in str(e):
                raise
            raise ValueError(
                f"env override REPRO_FAULT_PLAN: field {key!r} must be an "
                f"integer, got {val!r}"
            ) from None
    plan = FaultPlan(**kw)
    # An all-zero spec is a clean run: normalize to None so the engine
    # keeps the exact clean-path computation graph.
    return plan if plan.active else None


def _fault_hash(r, dst, src, seed: int, salt: int):
    """Counter-based per-edge uint32 hash (murmur-style finalizer) over
    ``(round, dst gid, src gid, plan seed, salt)``. Stateless and
    elementwise, so the masks it seeds are independent of sharding,
    substrate, and evaluation order — the property every
    cross-substrate-under-faults pin rests on."""
    x = (
        r.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
        + dst.astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
        + src.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D)
        + jnp.uint32((seed * 0x27D4EB2F + salt * 0x165667B1) & 0xFFFFFFFF)
    )
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def _fault_unit(r, dst, src, seed: int, salt: int):
    """Uniform [0, 1) f32 per (round, dst, src) edge."""
    return _fault_hash(r, dst, src, seed, salt).astype(jnp.float32) * jnp.float32(
        1.0 / 4294967296.0
    )


def _inject_faults(
    plan: FaultPlan,
    pod_of,
    r,
    dst_gids,
    src_gids,
    cert,
    due,
    dst_cert,
    depth: int,
):
    """Apply a :class:`FaultPlan` to one round's push candidates.

    ``cert`` is (W_local, m) f32 with +inf marking invalid entries —
    the common currency of every push path; ``src_gids`` is (W_local, m)
    i32 global source ids, ``dst_gids`` (W_local,) global destination
    ids, ``dst_cert`` (W_local,) the destinations' current (post-scan)
    certificates, ``due`` (W_local, m) i32 absolute delivery rounds or
    ``None`` on the dense-buffer paths (which cannot reorder).

    Order: drop (incl. pod partition) -> corrupt -> eps-gate soundness
    check -> due jitter -> duplicate mask. The soundness check rejects
    any candidate whose certificate is non-finite or >= the
    destination's current certificate: destination certificates are
    monotone non-increasing (worker contract), so an incoming cert
    ``>= cert_now`` can never satisfy the strict accept gate
    ``incoming < cert_later - eps`` for any eps >= 0 — rejection is
    provably harmless to the final certificates while keeping every
    corrupt value out of the pending queues.

    Returns ``(cert, due, dup_mask, n_dropped, n_rejected)`` — the
    caller turns ``dup_mask`` into extra queue entries (queue paths) or
    ignores it (dense buffer, where a duplicate write is a no-op)."""
    valid0 = jnp.isfinite(cert)
    dst2 = dst_gids[:, None]
    seed = int(plan.seed)
    drop = jnp.zeros(cert.shape, bool)
    if plan.drop_prob > 0.0:
        drop = _fault_unit(r, dst2, src_gids, seed, 1) < jnp.float32(plan.drop_prob)
    if pod_of is not None and 0 <= plan.partition_start < plan.partition_stop:
        in_window = (r >= plan.partition_start) & (r < plan.partition_stop)
        cross = pod_of[dst_gids][:, None] != pod_of[src_gids]
        drop = drop | (cross & in_window)
    drop = drop & valid0
    n_dropped = jnp.sum(drop, dtype=jnp.int32)

    live = valid0 & ~drop
    if plan.corrupt_prob > 0.0:
        cor = live & (
            _fault_unit(r, dst2, src_gids, seed, 2) < jnp.float32(plan.corrupt_prob)
        )
        sel = _fault_hash(r, dst2, src_gids, seed, 3) % jnp.uint32(3)
        bad = jnp.where(
            sel == 0,
            jnp.float32(jnp.nan),
            jnp.where(sel == 1, -jnp.inf, cert + jnp.float32(1e6)),
        )
        cert = jnp.where(cor, bad, cert)
    # eps-gate soundness check: reject non-finite / non-improving certs
    # before they can poison the pending state
    unsound = live & (~jnp.isfinite(cert) | (cert >= dst_cert[:, None]))
    n_rejected = jnp.sum(unsound, dtype=jnp.int32)

    keep = live & ~unsound
    cert = jnp.where(keep, cert, jnp.inf)
    if due is not None:
        if plan.reorder_max > 0:
            jit = (
                _fault_hash(r, dst2, src_gids, seed, 4)
                % jnp.uint32(plan.reorder_max + 1)
            ).astype(jnp.int32)
            due = jnp.minimum(due + jit, r + depth)
        due = jnp.where(keep, due, -1)
    dup = jnp.zeros(cert.shape, bool)
    if plan.duplicate_prob > 0.0:
        dup = keep & (
            _fault_unit(r, dst2, src_gids, seed, 5) < jnp.float32(plan.duplicate_prob)
        )
    return cert, due, dup, n_dropped, n_rejected


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_workers: int = 4
    eps: float = 0.0  # protocol gap; gates ACCEPTANCE only (as in the sim)
    max_rounds: int = 1000
    #: per-link broadcast latency in ROUNDS: an int (uniform) or a
    #: (W, W) ``delay[src, dst]`` integer array, clipped to >= 1. A
    #: message sent during round r is delivered at round r + delay.
    delay_rounds: Any = 1
    #: per-worker speed, cost units per simulated second; also drives
    #: the round-level compute credit (normalized to the fastest
    #: worker). None = uniform.
    speed: Any = None
    #: round index at which each worker fail-stops (None = never).
    fail_round: Any = None
    target_certificate: float | None = None
    seed: int = 0
    #: record per-worker certificate changes into SimResult.history
    record_history: bool = True
    #: rounds advanced per jitted dispatch (``lax.scan`` chunk). 1 =
    #: the old one-dispatch-per-round behavior; larger chunks amortize
    #: Python dispatch + host sync without changing any protocol
    #: semantics (exact rounds-to-target and per-round history are
    #: recovered from the stacked per-round info). Env-overridable so
    #: CI can rerun the whole tier chunked: REPRO_ROUNDS_PER_DISPATCH.
    rounds_per_dispatch: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_ROUNDS_PER_DISPATCH", 8)
    )
    #: cross-device gossip policy of the SHARDED engine (ignored on one
    #: device). "dense": all_gather every worker's model payload every
    #: round — O(W·payload) on the wire. "gated": all_gather only the
    #: cheap certificates + broadcast flags (W·5 bytes) densely; model
    #: payloads move only for each device's top-``gossip_top_k``
    #: locally-improved candidates — O(n_dev·k·payload). The eps gate
    #: still applies to ACCEPTANCE only; gating shapes traffic via the
    #: improvement test. Under uniform delay gated mode adopts models
    #: identical to dense mode (the per-round argmin is always among
    #: per-shard minima — pinned in tests/test_sharded_engine.py);
    #: under heterogeneous delay matrices it is an explicit
    #: approximation. Env-overridable: REPRO_GOSSIP_MODE.
    gossip_mode: str = dataclasses.field(
        default_factory=lambda: _env_str("REPRO_GOSSIP_MODE", "dense")
    )
    #: per-device candidate count for gated gossip (clamped to the
    #: shard's local worker count)
    gossip_top_k: int = 1
    #: cross-pod exchange cadence of the pod-mesh engine, in rounds
    #: (ignored without a ``pod`` mesh axis). 1 = flush the cross-pod
    #: tier every round, which under UNIFORM delay reproduces the flat
    #: single-axis engine bit-identically (pinned in
    #: tests/test_sharded_engine.py); k > 1 lets improvements accumulate
    #: in the pending tier and ships only the freshest certificates
    #: every k-th round over the DCN — an explicit approximation,
    #: measured by bench_scaling.py. Env: REPRO_CROSS_POD_EVERY_K.
    cross_pod_every_k: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_CROSS_POD_EVERY_K", 1)
    )
    #: per-device candidate count for each cross-pod flush (the PR 3
    #: top-k gated payload path applied to the pod axis; clamped to the
    #: shard's local worker count). Env: REPRO_CROSS_POD_TOP_K.
    cross_pod_top_k: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_CROSS_POD_TOP_K", 1)
    )
    #: bounded per-destination pending-queue capacity C for the
    #: in-flight state. 0 (default) keeps the dense ``(W, W, D)``
    #: certificate buffer — the exact oracle. C >= 1 replaces it with a
    #: per-destination ``(W, C)`` queue of pending (cert, src, due,
    #: ring-slot) entries, evicting worst-certificate-first on
    #: overflow: O(W·C) state instead of O(W²·D). When C covers the
    #: peak per-destination occupancy the sparse run is bit-identical
    #: to the dense oracle (``SimResult.messages_evicted == 0`` is the
    #: run-level witness); smaller C is an explicit, measured
    #: approximation — see docs/config.md. ``"auto"`` sizes C from a
    #: short warm-up occupancy probe at run() time: the probe's measured
    #: ``inflight_occupancy_peak`` × ``AUTO_CAPACITY_HEADROOM``, logged
    #: into ``SimResult.inflight_capacity_selected``. Env-overridable so
    #: a CI matrix leg can rerun the tier sparse:
    #: REPRO_INFLIGHT_CAPACITY (accepts ``auto``).
    inflight_capacity: Any = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_INFLIGHT_CAPACITY", 0, special=("auto",))
    )
    #: delivery implementation of the sparse path (ignored while
    #: ``inflight_capacity == 0``): "pallas" routes delivery-argmin +
    #: eps-gated accept + laggard-credit update through the fused
    #: ``kernels/round_step.py`` kernel (interpret mode off-TPU);
    #: "ref" uses the pure-jnp oracle in ``kernels/ref.py``. Both are
    #: bit-identical — pinned in tests. Env: REPRO_ROUND_STEP_IMPL.
    round_step_impl: str = dataclasses.field(
        default_factory=lambda: _env_str("REPRO_ROUND_STEP_IMPL", "pallas")
    )
    #: per-round control-plane exchange policy. "dense": every round
    #: moves a (W,) certificate (+ broadcast-flag) all_gather and the
    #: receivers scan/scatter the full width — O(W) wire and
    #: O(W_local·W) work per round even in gated gossip. "sparse": the
    #: exchange carries only each device's top-``gossip_top_k``
    #: locally-improved candidates as (cert, global_id, round) triples —
    #: a fixed-size (n_dev, k) all_gather, OOB-padded — and receivers
    #: scatter them into the pending queues / in-flight state by global
    #: id: O(n_dev·k), independent of W. Under UNIFORM delay sparse
    #: control is bit-identical to dense control (the delivery argmin is
    #: always among the per-device top improvers — pinned in
    #: tests/test_sparse_inflight.py); under heterogeneous delay it is a
    #: measured approximation (bench_scaling.py, control-plane section).
    #: Env-overridable: REPRO_CONTROL_PLANE.
    control_plane: str = dataclasses.field(
        default_factory=lambda: _env_str("REPRO_CONTROL_PLANE", "dense")
    )
    #: trailing worker rows pre-allocated as masked-out SPARES for
    #: elastic membership: they carry state and compile like any other
    #: row but start dead, so a :class:`MembershipPlan` join can
    #: activate one mid-run with zero recompilation. A spare without a
    #: scheduled join never activates. Env: REPRO_SPARE_SLOTS.
    spare_slots: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_SPARE_SLOTS", 0)
    )
    #: optional :class:`MembershipPlan` (joins into spare slots, leaves
    #: folded into ``fail_round``); programmatic only — schedules are
    #: structured data, not an env knob.
    membership: Any = None
    #: adversarial fault schedule at the gossip boundary: a
    #: :class:`FaultPlan` (programmatic, wins) or the
    #: ``REPRO_FAULT_PLAN`` spec string parsed by
    #: :func:`_parse_fault_spec` (e.g. ``"drop=5,corrupt=2,seed=9"``,
    #: integer percent). Empty = no injection, bit-identical clean
    #: semantics. The CI chaos leg drives this via the env; tests that
    #: pin engine-vs-oracle equivalence set ``fault_spec=""`` explicitly
    #: so the leg only steers env-following runs (same convention as
    #: the other matrix knobs). Env: REPRO_FAULT_PLAN.
    fault_spec: str = dataclasses.field(
        default_factory=lambda: _env_str("REPRO_FAULT_PLAN", "")
    )
    fault_plan: Any = None
    #: serving publish gate, in rounds: with a publisher attached
    #: (:meth:`TMSNEngine.attach_publisher`), the engine checks the
    #: ensemble's best certificate at the first chunk boundary at or
    #: after every k-th round and publishes that worker's model into
    #: the adoption slot when it improved. 0 (default) disables the
    #: check entirely — the clean engine takes no extra host syncs.
    #: Publishing is host-side and outside the jitted round step, so
    #: the protocol semantics and the compiled graph are unchanged
    #: either way. Env: REPRO_PUBLISH_EVERY_K.
    publish_every_k: int = dataclasses.field(
        default_factory=lambda: _env_int("REPRO_PUBLISH_EVERY_K", 0)
    )
    #: minimum best-certificate improvement (strict, in certificate
    #: units) over the previously published snapshot before a new one
    #: is published — the serving-edge analogue of the protocol's
    #: broadcast-on-improvement gate. 0.0 publishes on any strict
    #: improvement. Env: REPRO_PUBLISH_EPS.
    publish_eps: float = dataclasses.field(
        default_factory=lambda: _env_float("REPRO_PUBLISH_EPS", 0.0)
    )
    #: optional ``jax.sharding.Mesh``: a 1-D ``("workers",)`` mesh
    #: shards the worker axis over one interconnect tier; a 2-D
    #: ``("pod", "workers")`` mesh adds the hierarchical cross-pod tier
    #: (``launch/mesh.py::make_worker_mesh(pods=...)`` builds both).
    #: ``None`` or a 1-device mesh keeps the single-device path; a
    #: multi-device mesh makes :func:`make_engine` build the
    #: shard-mapped engine (``n_workers`` must divide evenly over the
    #: total device count).
    mesh: Any = None


class PendingQueue(NamedTuple):
    """Bounded per-destination pending-message state (the sparse
    replacement for the dense ``(W, W, D)`` in-flight buffer when
    :attr:`EngineConfig.inflight_capacity` > 0).

    Each destination row holds up to C pending messages; ``cert`` is
    +inf on empty slots. ``due`` is the ABSOLUTE delivery round, so a
    delivered entry only needs its cert cleared — a stale ``due`` can
    never match a later (monotonically increasing) round. ``slot`` is
    the snapshot-ring slot captured at push time (``push_round % D``),
    which equals the dense engine's payload lookup
    ``(r - delay[src, dst]) % D`` at delivery."""

    cert: jnp.ndarray  # (W, C) f32; +inf = empty
    src: jnp.ndarray  # (W, C) i32 global source worker id
    due: jnp.ndarray  # (W, C) i32 absolute delivery round (-1 = empty)
    slot: jnp.ndarray  # (W, C) i32 ring slot of the payload


def _empty_queue(w: int, capacity: int) -> PendingQueue:
    return PendingQueue(
        cert=jnp.full((w, capacity), jnp.inf, jnp.float32),
        src=jnp.zeros((w, capacity), jnp.int32),
        due=jnp.full((w, capacity), -1, jnp.int32),
        slot=jnp.zeros((w, capacity), jnp.int32),
    )


def _queue_push(
    queue: PendingQueue,
    score: jnp.ndarray,
    alive: jnp.ndarray,
    local_gids: jnp.ndarray,
    delay_rows: jnp.ndarray,
    r: jnp.ndarray,
    depth: int,
    dst_cert: jnp.ndarray | None = None,
    fault: FaultPlan | None = None,
    pod_of=None,
) -> tuple[PendingQueue, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Push this round's broadcast candidates into every local
    destination's pending queue, evicting worst-certificate-first.

    ``score`` is (W,) f32 over GLOBAL source ids: the candidate's
    certificate where that source broadcasts this round, +inf where it
    does not. This one shape serves every call site — single-device
    (``where(improved, certs, inf)``), the sharded tier-1 control plane
    (always dense-width, both gossip modes), and the pod-mesh cross-pod
    flush. ``alive`` (W_local,) masks destinations, ``local_gids``
    (W_local,) are the destinations' global ids (self-exclusion),
    ``delay_rows`` is (W_local, W) indexed [local dst, global src].

    Candidate pre-filter: only the globally best ``C + 1`` candidates
    can ever enter a kept top-C (a candidate ranked below C + 1 has at
    least C better non-self competitors at every destination), so the
    merge sorts (W_local, C + min(C+1, W)) instead of (W_local, C + W).
    Eviction keeps the lexicographically smallest C by (cert, src, due)
    — worst-certificate-first, ties dropping the higher source id, so
    the survivor set always contains every entry the dense delivery
    argmin could select.

    Returns ``(queue, n_pushed, n_evicted, occ_pre_max)``. The counters
    are LOGICAL (capacity-independent): ``n_pushed`` equals the dense
    engine's ``sum(push_mask)``; ``n_evicted`` counts every candidate
    offered but not retained (including pre-filtered ones — if anything
    was pre-filtered the queue provably fills to C, so the accounting
    stays exact); ``occ_pre_max`` is the peak pre-eviction occupancy.
    ``n_evicted == 0`` over a whole run certifies the sparse run as
    bit-identical to the dense oracle.

    With ``fault`` set, :func:`_inject_faults` runs on the candidate
    block before the merge (the pre-filter is applied PRE-fault, so its
    top-``C+1`` window is the clean run's); duplicates become extra
    candidate columns, and the occupancy/eviction accounting switches
    from the logical offer count to the post-fault effective one (a
    dropped message must not read as an eviction). Two extra counters
    ``(n_dropped, n_rejected)`` join the return tuple — zero when
    ``fault`` is None.
    """
    w = score.shape[0]
    wl, cap = queue.cert.shape
    k = min(cap + 1, w)
    order = jnp.argsort(score, stable=True)[:k].astype(jnp.int32)
    c_cert = score[order]  # (k,) sorted best candidates
    val = (
        jnp.isfinite(c_cert)[None, :]
        & (order[None, :] != local_gids[:, None])
        & alive[:, None]
    )
    cand_cert = jnp.where(val, c_cert[None, :], jnp.inf)  # (wl, k)
    cand_src = jnp.broadcast_to(order[None, :], (wl, k))
    cand_due = jnp.where(
        val, r + jnp.take_along_axis(delay_rows, cand_src, axis=1), -1
    )
    cand_slot = jnp.where(val, jnp.int32(r % depth), 0)

    n_dropped = jnp.zeros((), jnp.int32)
    n_rejected = jnp.zeros((), jnp.int32)
    if fault is not None:
        cand_cert, cand_due, dup, n_dropped, n_rejected = _inject_faults(
            fault, pod_of, r, local_gids, cand_src, cand_cert, cand_due,
            dst_cert, depth,
        )
        if fault.duplicate_prob > 0.0:
            cand_cert = jnp.concatenate(
                [cand_cert, jnp.where(dup, cand_cert, jnp.inf)], axis=1
            )
            cand_src = jnp.concatenate([cand_src, cand_src], axis=1)
            cand_due = jnp.concatenate(
                [cand_due, jnp.where(dup, cand_due, -1)], axis=1
            )
            cand_slot = jnp.concatenate([cand_slot, cand_slot], axis=1)

    m_cert = jnp.concatenate([queue.cert, cand_cert], axis=1)
    m_src = jnp.concatenate([queue.src, cand_src], axis=1)
    m_due = jnp.concatenate([queue.due, cand_due], axis=1)
    m_slot = jnp.concatenate([queue.slot, cand_slot], axis=1)
    keep = jnp.lexsort((m_due, m_src, m_cert), axis=-1)[:, :cap]
    new = PendingQueue(
        cert=jnp.take_along_axis(m_cert, keep, axis=1),
        src=jnp.take_along_axis(m_src, keep, axis=1),
        due=jnp.take_along_axis(m_due, keep, axis=1),
        slot=jnp.take_along_axis(m_slot, keep, axis=1),
    )

    n_bcast = jnp.sum(jnp.isfinite(score), dtype=jnp.int32)
    self_b = jnp.isfinite(score[local_gids]).astype(jnp.int32)
    n_cand = jnp.where(alive, n_bcast - self_b, 0)  # (wl,) logical offers
    if fault is not None:
        # occupancy math must use what actually reached the merge, or a
        # fault-dropped message would be double-counted as an eviction
        n_off = jnp.sum(jnp.isfinite(cand_cert), axis=1, dtype=jnp.int32)
    else:
        n_off = n_cand
    occ_pre = jnp.sum(jnp.isfinite(queue.cert), axis=1, dtype=jnp.int32) + n_off
    occ_after = jnp.sum(jnp.isfinite(new.cert), axis=1, dtype=jnp.int32)
    return (
        new,
        jnp.sum(n_cand, dtype=jnp.int32),
        jnp.sum(occ_pre - occ_after, dtype=jnp.int32),
        jnp.max(occ_pre),
        n_dropped,
        n_rejected,
    )


def _candidate_valid(
    cand_cert: jnp.ndarray,
    cand_ids: jnp.ndarray,
    alive: jnp.ndarray,
    local_gids: jnp.ndarray,
    w: int,
) -> jnp.ndarray:
    """(W_local, m) validity of each sparse-control candidate at each
    local destination: finite cert, in-range global id (OOB padding from
    the fixed-size all_gather carries id >= W), not the destination
    itself, destination alive."""
    return (
        jnp.isfinite(cand_cert)[None, :]
        & (cand_ids[None, :] != local_gids[:, None])
        & (cand_ids[None, :] < w)
        & alive[:, None]
    )


def _queue_push_candidates(
    queue: PendingQueue,
    cand_cert: jnp.ndarray,
    cand_ids: jnp.ndarray,
    alive: jnp.ndarray,
    local_gids: jnp.ndarray,
    delay_rows: jnp.ndarray,
    r: jnp.ndarray,
    depth: int,
    impl: str,
    dst_cert: jnp.ndarray | None = None,
    fault: FaultPlan | None = None,
    pod_of=None,
) -> tuple[PendingQueue, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sparse-control ingest: merge an explicit candidate list into the
    pending queues, evicting worst-certificate-first.

    Unlike :func:`_queue_push` (which scans a dense (W,) score vector),
    the candidates arrive as parallel (m,) arrays of certificates and
    global source ids — the payload of the (n_dev, k) control-plane
    all_gather, OOB-padded with ``id >= W`` / +inf certs. The merge runs
    through the candidate-list ingest kernel (``impl`` picks the Pallas
    kernel in ``kernels/round_step.py`` or the jnp reference in
    ``kernels/ref.py``; bit-identical by contract) under the same total
    order as :func:`_queue_push`'s lexsort, so the survivor set is
    identical to a dense-score push restricted to these candidates.

    Returns ``(queue, n_pushed, n_evicted, occ_pre_max, n_dropped,
    n_rejected)`` with the same counter semantics as :func:`_queue_push`
    (no pre-filter here, so every offered candidate is accounted
    directly; the trailing fault counters are zero without a plan).
    """
    w = delay_rows.shape[1]
    wl, m = delay_rows.shape[0], cand_ids.shape[0]
    ids_c = jnp.clip(cand_ids, 0, w - 1).astype(jnp.int32)
    val = _candidate_valid(cand_cert, cand_ids, alive, local_gids, w)
    c_cert = jnp.where(val, cand_cert[None, :], jnp.inf)
    c_src = jnp.broadcast_to(ids_c[None, :], (wl, m))
    c_due = jnp.where(val, r + jnp.take_along_axis(delay_rows, c_src, axis=1), -1)
    c_slot = jnp.where(val, jnp.int32(r % depth), 0)
    n_dropped = jnp.zeros((), jnp.int32)
    n_rejected = jnp.zeros((), jnp.int32)
    if fault is not None:
        c_cert, c_due, dup, n_dropped, n_rejected = _inject_faults(
            fault, pod_of, r, local_gids, c_src, c_cert, c_due, dst_cert, depth
        )
        if fault.duplicate_prob > 0.0:
            c_cert = jnp.concatenate([c_cert, jnp.where(dup, c_cert, jnp.inf)], axis=1)
            c_src = jnp.concatenate([c_src, c_src], axis=1)
            c_due = jnp.concatenate([c_due, jnp.where(dup, c_due, -1)], axis=1)
            c_slot = jnp.concatenate([c_slot, c_slot], axis=1)
    if impl == "ref":
        from repro.kernels.ref import queue_ingest_ref as ingest
    else:
        from repro.kernels.ops import queue_ingest as ingest
    q_cert, q_due, q_src, q_slot = ingest(
        queue.cert, queue.due, queue.src, queue.slot, c_cert, c_due, c_src, c_slot
    )
    new = PendingQueue(cert=q_cert, src=q_src, due=q_due, slot=q_slot)
    n_cand = jnp.sum(val, axis=1, dtype=jnp.int32)  # (wl,) offers
    if fault is not None:
        n_off = jnp.sum(jnp.isfinite(c_cert), axis=1, dtype=jnp.int32)
    else:
        n_off = n_cand
    occ_pre = jnp.sum(jnp.isfinite(queue.cert), axis=1, dtype=jnp.int32) + n_off
    occ_after = jnp.sum(jnp.isfinite(new.cert), axis=1, dtype=jnp.int32)
    return (
        new,
        jnp.sum(n_cand, dtype=jnp.int32),
        jnp.sum(occ_pre - occ_after, dtype=jnp.int32),
        jnp.max(occ_pre),
        n_dropped,
        n_rejected,
    )


def _dense_push_candidates(
    inflight: jnp.ndarray,
    cand_cert: jnp.ndarray,
    cand_ids: jnp.ndarray,
    alive: jnp.ndarray,
    local_gids: jnp.ndarray,
    delay_rows: jnp.ndarray,
    r: jnp.ndarray | None = None,
    dst_cert: jnp.ndarray | None = None,
    fault: FaultPlan | None = None,
    pod_of=None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Sparse-control push into the dense ``(W_local, W, D)`` in-flight
    buffer (``inflight_capacity == 0``): scatter each candidate's
    certificate at ``[dst, src, delay-1]`` by global id — O(W_local·m)
    scatter work instead of the O(W_local·W·D) dense push mask. Invalid
    candidates scatter to the OOB source index W and drop. With a
    ``fault`` plan, dropped/rejected candidates also go OOB (duplication
    is a no-op on the dense buffer — the same cell written twice — and
    reorder is rejected at construction). Returns ``(inflight, n_pushed,
    n_dropped, n_rejected)``."""
    w = delay_rows.shape[1]
    wl, m = delay_rows.shape[0], cand_ids.shape[0]
    ids_c = jnp.clip(cand_ids, 0, w - 1).astype(jnp.int32)
    val = _candidate_valid(cand_cert, cand_ids, alive, local_gids, w)
    cert2 = jnp.where(val, cand_cert[None, :], jnp.inf)  # (wl, m) per-edge
    n_dropped = jnp.zeros((), jnp.int32)
    n_rejected = jnp.zeros((), jnp.int32)
    if fault is not None:
        src2 = jnp.broadcast_to(ids_c[None, :], (wl, m))
        cert2, _, _, n_dropped, n_rejected = _inject_faults(
            fault, pod_of, r, local_gids, src2, cert2, None, dst_cert, depth=0
        )
        val = val & jnp.isfinite(cert2)
    ids2 = jnp.where(val, cand_ids[None, :], w)  # OOB -> dropped
    d = jnp.take_along_axis(delay_rows, jnp.broadcast_to(ids_c[None, :], (wl, m)), axis=1)
    row_idx = jnp.broadcast_to(jnp.arange(wl, dtype=jnp.int32)[:, None], (wl, m))
    inflight = inflight.at[row_idx, ids2, d - 1].set(cert2, mode="drop")
    return inflight, jnp.sum(val, dtype=jnp.int32), n_dropped, n_rejected


class EngineState(NamedTuple):
    worker: Any
    certs: jnp.ndarray  # (W,) f32 — post-round certificates, carried so
    # the next round's acceptance test needs no third certificates() call
    alive: jnp.ndarray  # (W,) bool
    credit: jnp.ndarray  # (W,) f32 compute credit (laggard model)
    clock: jnp.ndarray  # (W,) f32 per-worker simulated seconds
    #: dense mode: (W, W, D) f32 — [dst, src, d] certs, +inf = empty.
    #: sparse mode (inflight_capacity > 0): a :class:`PendingQueue`
    inflight: Any
    ring: Any  # model snapshots, leading (D, W) — (n_pods*D, W) on a pod mesh
    round: jnp.ndarray  # () i32
    sent: jnp.ndarray  # () i32
    accepted: jnp.ndarray  # () i32
    discarded: jnp.ndarray  # () i32
    cost_total: jnp.ndarray  # () f32
    #: (W,) bool — cross-pod tier: workers whose improvement is pending
    #: the next pod-axis flush (constant False off the pod-mesh engine)
    xpend: jnp.ndarray
    #: () i32 — pushes that crossed a pod boundary (DCN tier); a
    #: (n_dev,) per-shard partial on the sharded engines, like `sent`
    sent_dcn: jnp.ndarray
    #: () i32 — sparse-mode candidates offered but not retained
    #: (capacity evictions); constant 0 in dense mode and, like `sent`,
    #: a (n_dev,) per-shard partial on the sharded engines
    evicted: jnp.ndarray
    #: () i32 — peak pre-eviction pending-queue occupancy seen by any
    #: destination (a measured lower bound on the capacity that makes
    #: the run exact); (n_dev,) per-shard partials when sharded
    occ_peak: jnp.ndarray
    #: () i32 — messages dropped by FaultPlan injection (random drop
    #: plus partition-window drops); (n_dev,) partials when sharded
    dropped_inj: jnp.ndarray
    #: () i32 — candidates rejected by the eps-gate soundness check
    #: (non-finite or non-improving certs, active only under a
    #: FaultPlan); (n_dev,) partials when sharded
    corrupt_rej: jnp.ndarray


class RoundInfo(NamedTuple):
    """Small per-round summary fetched to the host for history/stop."""

    certs: jnp.ndarray  # (W,)
    changed: jnp.ndarray  # (W,) bool — cert changed this round (fire or adopt)
    clock: jnp.ndarray  # (W,)
    alive: jnp.ndarray  # (W,)


def _tree_stack_rows(tree: Any, depth: int) -> Any:
    """Tile a stacked (W, ...) pytree into a (D, W, ...) ring."""
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a[None], (depth,) + a.shape).copy(), tree
    )


class TMSNEngine:
    """Round-based TMSN run over a batched worker."""

    def __init__(self, worker: BatchedTMSNWorker, config: EngineConfig) -> None:
        self.worker = worker
        self.config = config
        w = config.n_workers

        if config.gossip_mode not in ("dense", "gated"):
            raise ValueError(
                f"gossip_mode must be 'dense' or 'gated', got {config.gossip_mode!r}"
            )
        if config.gossip_top_k < 1:
            raise ValueError(f"gossip_top_k must be >= 1, got {config.gossip_top_k}")
        if config.rounds_per_dispatch < 1:
            raise ValueError(
                f"rounds_per_dispatch must be >= 1, got {config.rounds_per_dispatch}"
            )
        if config.cross_pod_every_k < 1:
            raise ValueError(
                f"cross_pod_every_k must be >= 1, got {config.cross_pod_every_k}"
            )
        if config.cross_pod_top_k < 1:
            raise ValueError(
                f"cross_pod_top_k must be >= 1, got {config.cross_pod_top_k}"
            )
        if isinstance(config.inflight_capacity, str):
            if config.inflight_capacity != "auto":
                raise ValueError(
                    f"inflight_capacity must be an int >= 0 or 'auto', "
                    f"got {config.inflight_capacity!r}"
                )
        elif config.inflight_capacity < 0:
            raise ValueError(
                f"inflight_capacity must be >= 0, got {config.inflight_capacity}"
            )
        if config.round_step_impl not in ("pallas", "ref"):
            raise ValueError(
                f"round_step_impl must be 'pallas' or 'ref', got {config.round_step_impl!r}"
            )
        if config.control_plane not in ("dense", "sparse"):
            raise ValueError(
                f"control_plane must be 'dense' or 'sparse', got {config.control_plane!r}"
            )
        if config.publish_every_k < 0:
            raise ValueError(
                f"publish_every_k must be >= 0, got {config.publish_every_k}"
            )
        if not config.publish_eps >= 0.0:  # also rejects NaN
            raise ValueError(f"publish_eps must be >= 0, got {config.publish_eps}")
        #: serving-tier publisher (an AdoptionSlot-shaped object); None
        #: until attach_publisher() — the clean run() path stays free of
        #: the per-chunk certificate fetch
        self._publisher: Any = None
        self._published_cert = float("inf")
        self._next_publish_round = 0
        self._control_sparse = config.control_plane == "sparse"
        #: 0 = dense (W, W, D) oracle; C >= 1 = bounded PendingQueue;
        #: None = "auto", resolved by a warm-up probe at run() time
        self._capacity: int | None = (
            None
            if config.inflight_capacity == "auto"
            else int(config.inflight_capacity)
        )
        #: capacity the auto probe selected (0 when capacity is explicit)
        self._auto_selected = 0

        delay = np.asarray(config.delay_rounds)
        if delay.ndim == 0:
            delay = np.full((w, w), int(delay))
        if delay.shape != (w, w):
            raise ValueError(f"delay_rounds must be scalar or ({w},{w}), got {delay.shape}")
        self._delay = jnp.asarray(np.maximum(delay, 1), jnp.int32)
        self._depth = int(np.maximum(delay, 1).max())

        speed = np.ones(w) if config.speed is None else np.asarray(config.speed, np.float64)
        if speed.shape != (w,):
            raise ValueError(f"speed must be ({w},), got {speed.shape}")
        self._speed = jnp.asarray(speed, jnp.float32)
        self._speed_norm = jnp.asarray(speed / speed.max(), jnp.float32)

        fail = (
            np.full(w, np.iinfo(np.int32).max)
            if config.fail_round is None
            else np.asarray(config.fail_round).copy()
        )
        if fail.shape != (w,):
            raise ValueError(f"fail_round must be ({w},), got {fail.shape}")

        # --- elastic membership: spares, joins, leaves ---------------------
        spares = int(config.spare_slots)
        if not 0 <= spares < w:
            raise ValueError(
                f"spare_slots must be in [0, n_workers), got {spares} (n_workers={w})"
            )
        never = np.iinfo(np.int32).max
        join_round = np.zeros(w, np.int64)
        if spares:
            join_round[w - spares :] = never  # spares without a join stay masked
        plan = config.membership
        if plan is not None:
            if not isinstance(plan, MembershipPlan):
                raise ValueError(
                    f"membership must be a MembershipPlan, got {type(plan).__name__}"
                )
            seen_slots: set[int] = set()
            for k, slot in plan.joins:
                k, slot = int(k), int(slot)
                if k < 1:
                    raise ValueError(f"membership join rounds are 1-based, got {k}")
                if not w - spares <= slot < w:
                    raise ValueError(
                        f"membership join slot {slot} is not a spare "
                        f"(spare region is [{w - spares}, {w}), "
                        f"spare_slots={spares})"
                    )
                if slot in seen_slots:
                    raise ValueError(f"membership joins slot {slot} twice")
                seen_slots.add(slot)
                join_round[slot] = k - 1  # 1-based: k=1 == alive from round 0
            for k, leaver in plan.leaves:
                k, leaver = int(k), int(leaver)
                if k < 1:
                    raise ValueError(f"membership leave rounds must be >= 1, got {k}")
                if not 0 <= leaver < w:
                    raise ValueError(
                        f"membership leave worker {leaver} out of range [0, {w})"
                    )
                fail[leaver] = min(int(fail[leaver]), k)
        self._join_round_np = join_round
        self._join_round = jnp.asarray(join_round, jnp.int32)
        #: joins/spares change the alive/credit dataflow; keep the clean
        #: engine's exact graph when the feature is off
        self._has_joins = spares > 0 or (plan is not None and bool(plan.joins))
        self._fail_round = jnp.asarray(fail, jnp.int32)

        # --- fault injection -----------------------------------------------
        fplan = config.fault_plan
        if fplan is None:
            fplan = _parse_fault_spec(config.fault_spec)
        elif not isinstance(fplan, FaultPlan):
            raise ValueError(
                f"fault_plan must be a FaultPlan, got {type(fplan).__name__}"
            )
        if fplan is not None:
            for fname in ("drop_prob", "duplicate_prob", "corrupt_prob"):
                p = getattr(fplan, fname)
                if not 0.0 <= p <= 1.0:
                    raise ValueError(f"FaultPlan.{fname} must be in [0, 1], got {p}")
            if fplan.reorder_max < 0:
                raise ValueError(
                    f"FaultPlan.reorder_max must be >= 0, got {fplan.reorder_max}"
                )
            if fplan.reorder_max > 0 and self._capacity == 0:
                raise ValueError(
                    "FaultPlan.reorder_max > 0 needs the pending-queue in-flight "
                    "state (inflight_capacity >= 1 or 'auto'): the dense (W, W, D) "
                    "buffer derives ring slots from the static delay matrix, so a "
                    "jittered delivery would fetch a wrong-generation payload"
                )
            if not fplan.active:
                fplan = None  # all-zero plan == clean semantics, same graph
        self._fault: FaultPlan | None = fplan
        #: (W,) pod index per global worker id on the pod-mesh engine
        #: (set by the sharded subclass); None = no pod geometry, which
        #: makes the FaultPlan partition window inert
        self._pod_of = None

        #: compiled chunk dispatchers keyed by scan length (the main
        #: chunk size plus at most one remainder length per run)
        self._chunks: dict[int, Any] = {}

        #: workers without a sampling phase omit the resample hooks and
        #: the round step statically drops the whole resample branch
        self._has_resample = has_resample_hooks(worker)
        #: traffic-accounting payload size: the worker's own
        #: payload_bytes() when defined, else derived from the exported
        #: model pytree via jax.eval_shape (cannot drift from reality)
        self._payload_bytes = resolve_payload_bytes(worker, w, config.seed)

    # ------------------------------------------------------------------
    # dispatch chunking: K rounds per jitted call via lax.scan
    # ------------------------------------------------------------------
    def _chunk_body(self, step, any_reduce):
        """Scan body ``(state, done), _ -> ((state, done), RoundInfo)``.

        ``step`` is the (possibly shard-mapped) single-round step;
        ``any_reduce`` turns a (local) boolean vector into a scalar
        "any worker, any shard" — ``jnp.any`` on one device, a psum on
        the sharded engine. When ``target_certificate`` is set, ``done``
        freezes the carried state on the crossing round so the final
        state is identical to an unchunked run for every chunk size.
        """
        target = self.config.target_certificate

        def frozen(state):
            # post-crossing rounds: state passes through untouched and
            # the round reports no changes (so host history/stop logic
            # sees the crossing round as the last live one)
            info = RoundInfo(
                certs=state.certs,
                changed=jnp.zeros_like(state.alive),
                clock=state.clock,
                alive=state.alive,
            )
            return state, info

        def body(carry, _):
            state, done = carry
            if target is None:
                new_state, info = step(state)
            else:
                # cond, not select: once done, the remaining rounds of
                # the chunk skip the whole step (worker scan, gossip
                # collectives, ring writes) instead of computing and
                # discarding it. `done` derives from an all-shard
                # reduction, so every device takes the same branch and
                # the collectives inside stay uniform.
                new_state, info = jax.lax.cond(done, frozen, step, state)
                done = done | any_reduce(info.alive & (info.certs <= target))
            return (new_state, done), info

        return body

    def _build_chunk(self, length: int):
        """Jitted ``state -> (state, RoundInfo stacked over length)``;
        the sharded engine overrides this to run the scan inside
        ``shard_map``."""
        body = self._chunk_body(self._round_step, jnp.any)

        def chunk(state: EngineState):
            (state, _), infos = jax.lax.scan(
                body, (state, jnp.zeros((), bool)), None, length=length
            )
            return state, infos

        return jax.jit(chunk)

    def _chunk_fn(self, length: int):
        fn = self._chunks.get(length)
        if fn is None:
            fn = self._chunks[length] = self._build_chunk(length)
        return fn

    # ------------------------------------------------------------------
    def _init_state(self) -> EngineState:
        cfg = self.config
        w, d = cfg.n_workers, self._depth
        wstate = self.worker.init_batch(w, cfg.seed)
        models = self.worker.export_models(wstate)
        if self._capacity:
            inflight = _empty_queue(w, self._capacity)
        else:
            inflight = jnp.full((w, w, d), jnp.inf, jnp.float32)
        if self._has_joins:
            alive0 = jnp.asarray(self._join_round_np <= 0)
        else:
            alive0 = jnp.ones((w,), bool)
        return EngineState(
            worker=wstate,
            certs=jnp.asarray(self.worker.certificates(wstate), jnp.float32),
            alive=alive0,
            credit=jnp.zeros((w,), jnp.float32),
            clock=jnp.zeros((w,), jnp.float32),
            inflight=inflight,
            ring=_tree_stack_rows(models, d),
            round=jnp.zeros((), jnp.int32),
            sent=jnp.zeros((), jnp.int32),
            accepted=jnp.zeros((), jnp.int32),
            discarded=jnp.zeros((), jnp.int32),
            cost_total=jnp.zeros((), jnp.float32),
            xpend=jnp.zeros((w,), bool),
            sent_dcn=jnp.zeros((), jnp.int32),
            evicted=jnp.zeros((), jnp.int32),
            occ_peak=jnp.zeros((), jnp.int32),
            dropped_inj=jnp.zeros((), jnp.int32),
            corrupt_rej=jnp.zeros((), jnp.int32),
        )

    def _deliver_sparse(
        self,
        queue: PendingQueue,
        certs0: jnp.ndarray,
        alive: jnp.ndarray,
        credit: jnp.ndarray,
        speed_norm: jnp.ndarray,
        r: jnp.ndarray,
    ):
        """Fused sparse delivery: argmin over this round's due entries
        (ties to the lowest source id, matching the dense argmin),
        eps-gated accept, arrival clearing, and the laggard-credit
        update — one kernel call (``round_step_impl`` picks the Pallas
        kernel or the jnp reference; both are bit-identical).

        Returns ``(queue', best_cert, best_src, best_slot, take,
        n_arrivals, credit', active)``; the imports are deferred so
        ``repro.core.engine`` never pulls the kernels package (and its
        worker-side dependencies) at module import time.
        """
        if self.config.round_step_impl == "ref":
            from repro.kernels.ref import round_step_ref as deliver
        else:
            from repro.kernels.ops import round_deliver as deliver
        q_cert, best_cert, best_src, best_slot, take, n_arr, credit2, active = deliver(
            queue.cert,
            queue.due,
            queue.src,
            queue.slot,
            certs0,
            alive,
            credit,
            speed_norm,
            r,
            eps=float(self.config.eps),
        )
        return (
            queue._replace(cert=q_cert),
            best_cert,
            best_src,
            best_slot,
            take,
            jnp.sum(n_arr, dtype=jnp.int32),
            credit2,
            active,
        )

    def _top_k_candidates(self, mask, certs, k: int):
        """Rows of the (locally) best k candidates under ``mask`` and a
        validity flag per row. Stable argsort: ties pick the lowest
        worker row, matching the delivery argmin's tie-break. Shared by
        gated payload gossip, the cross-pod flush, and the sparse
        control plane."""
        score = jnp.where(mask, certs, jnp.inf)
        rows = jnp.argsort(score, stable=True)[:k]
        return rows, jnp.isfinite(score[rows])

    def _round_step(self, state: EngineState) -> tuple[EngineState, RoundInfo]:
        cfg = self.config
        w, depth = cfg.n_workers, self._depth
        r = state.round
        dst_idx = jnp.arange(w)
        if self._has_joins:
            # joins are sticky (state.alive | ...) and compose with
            # fail-stop; a joiner's laggard credit is reseeded on its
            # join round (the accumulator accrued while it was masked).
            # Its model/PRNG rows were never touched while masked
            # (worker contract), so its batch stream is the untouched
            # init_batch stream — no recompilation, no state surgery.
            alive = (state.alive | (r >= self._join_round)) & (r < self._fail_round)
            credit_in = jnp.where(r == self._join_round, 0.0, state.credit)
        else:
            alive = state.alive & (r < self._fail_round)
            credit_in = state.credit

        # last round's post-scan certificates, carried in the state (no
        # third certificates() call per round)
        certs0 = state.certs

        # --- 1.+2.(+3. credit) deliver arrivals due this round ------------
        if self._capacity:
            # sparse path: delivery argmin + accept gate + credit are
            # one fused kernel call; clearing the delivered certs
            # replaces the dense buffer shift (dues are absolute)
            (
                inflight,
                best_cert,
                best_src,
                sent_slot,
                take,
                n_arrivals,
                credit,
                active,
            ) = self._deliver_sparse(
                state.inflight, certs0, alive, credit_in, self._speed_norm, r
            )
        else:
            arr = state.inflight[:, :, 0]  # (dst, src) certs
            arr_live = jnp.where(alive[:, None], arr, jnp.inf)
            best_src = jnp.argmin(arr_live, axis=1)  # (W,)
            best_cert = arr_live[dst_idx, best_src]
            take = accepts(certs0, best_cert, cfg.eps) & jnp.isfinite(best_cert)
            n_arrivals = jnp.sum(jnp.isfinite(arr), dtype=jnp.int32)
            sent_slot = (r - self._delay[best_src, dst_idx]) % depth
            # shift the in-flight buffer
            inflight = jnp.concatenate(
                [state.inflight[:, :, 1:], jnp.full((w, w, 1), jnp.inf, jnp.float32)],
                axis=2,
            )
            credit = credit_in + self._speed_norm
            active = alive & (credit >= 1.0 - 1e-6)
            credit = jnp.where(active, credit - 1.0, credit)
        n_taken = jnp.sum(take, dtype=jnp.int32)

        in_models = jax.tree_util.tree_map(
            lambda a: a[sent_slot, best_src], state.ring
        )

        def _adopt(operand):
            wstate, models, c, t = operand
            return self.worker.adopt_batch(wstate, models, c, t)

        wstate, adopt_cost = jax.lax.cond(
            jnp.any(take),
            _adopt,
            lambda operand: (operand[0], jnp.zeros((w,), jnp.float32)),
            (state.worker, in_models, best_cert, take),
        )

        # --- 3. one segment per live, credit-covered worker ---------------
        # (workers without the optional resample hooks skip this branch
        # statically — see repro.core.worker.has_resample_hooks)
        if self._has_resample:
            need = self.worker.needs_resample(wstate) & active
            wstate, resample_cost = jax.lax.cond(
                jnp.any(need),
                lambda op: self.worker.resample_round(op[0], op[1]),
                lambda op: (op[0], jnp.zeros((w,), jnp.float32)),
                (wstate, need),
            )
            scan_mask = active & ~need
        else:
            resample_cost = jnp.zeros((w,), jnp.float32)
            scan_mask = active
        certs_pre = self.worker.certificates(wstate)
        wstate, scan_cost, fired = self.worker.scan_round(wstate, scan_mask)
        certs = self.worker.certificates(wstate)

        cost = adopt_cost + resample_cost + scan_cost
        clock = state.clock + cost / jnp.maximum(self._speed, 1e-12)

        # --- 4. broadcast strict improvements -----------------------------
        # (eps gates acceptance only — see the note in simulator.run)
        improved = fired & improves(certs_pre, certs, 0.0) & scan_mask
        n_evicted = jnp.zeros((), jnp.int32)
        occ_pre_max = jnp.zeros((), jnp.int32)
        n_dropped = jnp.zeros((), jnp.int32)
        n_rejected = jnp.zeros((), jnp.int32)
        if self._control_sparse:
            # sparse control plane: only the top-k improvers are offered
            # (single-device analogue of the (n_dev, k) all_gather). The
            # suppressed runner-ups could never have been accepted under
            # uniform delay — every receiver's best arrival is the
            # global min, except the min's own sender, whose local cert
            # is already at least as good as any runner-up.
            kc = min(int(cfg.gossip_top_k), w)
            rows, validk = self._top_k_candidates(improved, certs, kc)
            cand_ids = jnp.where(validk, rows.astype(jnp.int32), w)
            cand_certs = jnp.where(validk, certs[rows], jnp.inf)
            if self._capacity:
                (
                    inflight,
                    n_pushed,
                    n_evicted,
                    occ_pre_max,
                    n_dropped,
                    n_rejected,
                ) = _queue_push_candidates(
                    inflight,
                    cand_certs,
                    cand_ids,
                    alive,
                    dst_idx.astype(jnp.int32),
                    self._delay.T,  # (dst, src) rows
                    r,
                    depth,
                    cfg.round_step_impl,
                    dst_cert=certs,
                    fault=self._fault,
                    pod_of=self._pod_of,
                )
            else:
                inflight, n_pushed, n_dropped, n_rejected = _dense_push_candidates(
                    inflight,
                    cand_certs,
                    cand_ids,
                    alive,
                    dst_idx.astype(jnp.int32),
                    self._delay.T,
                    r=r,
                    dst_cert=certs,
                    fault=self._fault,
                    pod_of=self._pod_of,
                )
        elif self._capacity:
            (
                inflight,
                n_pushed,
                n_evicted,
                occ_pre_max,
                n_dropped,
                n_rejected,
            ) = _queue_push(
                inflight,
                jnp.where(improved, certs, jnp.inf),
                alive,
                dst_idx,
                self._delay.T,  # (dst, src) rows
                r,
                depth,
                dst_cert=certs,
                fault=self._fault,
                pod_of=self._pod_of,
            )
        elif self._fault is None:
            d_idx = jnp.arange(depth)[None, None, :]
            # push_mask[dst, src, d] — delay is indexed [src, dst]
            push_mask = (
                improved[None, :, None]
                & alive[:, None, None]
                & (dst_idx[:, None] != dst_idx[None, :])[:, :, None]
                & (d_idx == (self._delay.T[:, :, None] - 1))
            )
            inflight = jnp.where(push_mask, certs[None, :, None], inflight)
            n_pushed = jnp.sum(push_mask, dtype=jnp.int32)
        else:
            # faulted dense push: same mask, but carried as a per-edge
            # (dst, src) certificate matrix so _inject_faults can drop /
            # corrupt / soundness-reject individual edges
            push2 = (
                improved[None, :]
                & alive[:, None]
                & (dst_idx[:, None] != dst_idx[None, :])
            )
            cert_mat = jnp.where(push2, certs[None, :], jnp.inf)
            src_mat = jnp.broadcast_to(
                dst_idx[None, :].astype(jnp.int32), (w, w)
            )
            cert_mat, _, _, n_dropped, n_rejected = _inject_faults(
                self._fault,
                self._pod_of,
                r,
                dst_idx.astype(jnp.int32),
                src_mat,
                cert_mat,
                None,
                certs,
                depth,
            )
            d_idx = jnp.arange(depth)[None, None, :]
            push_mask = jnp.isfinite(cert_mat)[:, :, None] & (
                d_idx == (self._delay.T[:, :, None] - 1)
            )
            inflight = jnp.where(push_mask, cert_mat[:, :, None], inflight)
            n_pushed = jnp.sum(push2, dtype=jnp.int32)  # logical sends

        # --- 5. snapshot the models into the ring -------------------------
        # gated to broadcasters: ring[slot, src] is only ever read for a
        # message src pushed at that slot's round, so non-improved
        # workers keep their (dead) old entry instead of paying a write
        models = self.worker.export_models(wstate)
        ring = jax.tree_util.tree_map(
            lambda buf, m: buf.at[r % depth].set(
                jnp.where(
                    improved.reshape((-1,) + (1,) * (m.ndim - 1)), m, buf[r % depth]
                )
            ),
            state.ring,
            models,
        )

        new_state = EngineState(
            worker=wstate,
            certs=certs,
            alive=alive,
            credit=credit,
            clock=clock,
            inflight=inflight,
            ring=ring,
            round=r + 1,
            sent=state.sent + n_pushed,
            accepted=state.accepted + n_taken,
            discarded=state.discarded + (n_arrivals - n_taken),
            cost_total=state.cost_total + jnp.sum(cost),
            xpend=state.xpend,
            sent_dcn=state.sent_dcn,
            evicted=state.evicted + n_evicted,
            occ_peak=jnp.maximum(state.occ_peak, occ_pre_max),
            dropped_inj=state.dropped_inj + n_dropped,
            corrupt_rej=state.corrupt_rej + n_rejected,
        )
        info = RoundInfo(
            certs=certs, changed=take | improved, clock=clock, alive=alive
        )
        return new_state, info

    # ------------------------------------------------------------------
    def _resolve_auto_capacity(self) -> None:
        """Resolve ``inflight_capacity="auto"``: run a short warm-up
        probe at an explicit capacity, doubling until nothing is evicted
        (so the measured ``inflight_occupancy_peak`` is the true
        unbounded peak, not a capacity-truncated one), then size the
        real run's queues at peak × :data:`AUTO_CAPACITY_HEADROOM`. The
        probe inherits every protocol knob (same engine class, same
        mesh), so its occupancy is the run's own warm-up occupancy."""
        cfg = self.config
        w = cfg.n_workers
        warmup = min(max(2 * self._depth + 2, 8), cfg.max_rounds)
        hard_max = w * self._depth  # every (src, pending-round) pair
        probe_cap = min(max(64, 2 * self._depth), hard_max)
        while True:
            probe_cfg = dataclasses.replace(
                cfg,
                inflight_capacity=int(probe_cap),
                max_rounds=warmup,
                target_certificate=None,
                record_history=False,
            )
            probe = make_engine(self.worker, probe_cfg)
            res = probe.run()
            if res.messages_evicted == 0 or probe_cap >= hard_max:
                break
            probe_cap = min(2 * probe_cap, hard_max)
        peak = max(int(res.inflight_occupancy_peak), 0)
        self._capacity = max(1, math.ceil(peak * AUTO_CAPACITY_HEADROOM))
        self._auto_selected = self._capacity

    def attach_publisher(self, slot: Any) -> None:
        """Register a snapshot publisher (anything with a
        ``publish(params, cert, round)`` method — canonically a
        :class:`repro.launch.serving.AdoptionSlot`). At the first chunk
        boundary at/after every ``publish_every_k``-th round, :meth:`run`
        exports the best-certificate worker's model and publishes it when
        the certificate improved by more than ``publish_eps`` since the
        last publish. Host-side only: the jitted round step is untouched,
        and :class:`~repro.core.engine_sharded.ShardedTMSNEngine` inherits
        the hook unchanged (chunk outputs are global arrays)."""
        if self.config.publish_every_k < 1:
            raise ValueError(
                "attach_publisher requires publish_every_k >= 1 "
                f"(got {self.config.publish_every_k}); set it in EngineConfig "
                "or via REPRO_PUBLISH_EVERY_K"
            )
        self._publisher = slot

    def _maybe_publish(self, state: EngineState, rounds: int, final: bool = False) -> None:
        """Publish the best-certificate model if due and improved."""
        if self._publisher is None:
            return
        if not final and rounds < self._next_publish_round:
            return
        k = int(self.config.publish_every_k)
        while self._next_publish_round <= rounds:
            self._next_publish_round += k
        live = np.where(np.asarray(state.alive), np.asarray(state.certs), np.inf)
        best = int(np.argmin(live))
        best_cert = float(live[best])
        if not np.isfinite(best_cert):
            return
        if best_cert >= self._published_cert - float(self.config.publish_eps):
            return
        models = self.worker.export_models(state.worker)
        params = jax.tree_util.tree_map(lambda a: np.asarray(a[best]), models)
        self._publisher.publish(params, cert=best_cert, round=rounds)
        self._published_cert = best_cert

    def run(self) -> SimResult:
        cfg = self.config
        if self._capacity is None:
            self._resolve_auto_capacity()
        # each run() publishes from scratch: the first due boundary with
        # a finite best certificate publishes unconditionally
        self._published_cert = float("inf")
        self._next_publish_round = max(int(cfg.publish_every_k), 1)
        state = self._init_state()
        certs0 = np.asarray(state.certs)
        history: list[tuple[float, int, float]] = [
            (0.0, i, float(certs0[i])) for i in range(cfg.n_workers)
        ]

        rounds = 0
        # only fetch per-chunk info to the host when something consumes
        # it — a fixed-round throughput run stays free of device syncs
        # so JAX can queue whole chunks asynchronously
        fetch = cfg.record_history or cfg.target_certificate is not None
        k = int(cfg.rounds_per_dispatch)  # validated >= 1 in __init__
        remaining = int(cfg.max_rounds)
        while remaining > 0:
            kk = min(k, remaining)
            state, infos = self._chunk_fn(kk)(state)
            remaining -= kk
            if not fetch:
                rounds += kk
                self._maybe_publish(state, rounds)
                continue
            certs_k = np.asarray(infos.certs)  # (kk, W)
            stop = None
            if cfg.target_certificate is not None:
                # f32 target, matching the in-scan freeze comparison —
                # a float64 host compare could disagree with the device
                # in the ULP window around a non-f32-representable target
                hit = np.any(
                    (certs_k <= np.float32(cfg.target_certificate))
                    & np.asarray(infos.alive),
                    axis=1,
                )
                if hit.any():
                    stop = int(np.argmax(hit))
            last = kk - 1 if stop is None else stop
            rounds += last + 1
            if cfg.record_history:
                # bulk append over the stacked chunk: row-major nonzero
                # keeps (round, worker) order identical to the old
                # per-round per-worker Python loop
                changed_k = np.asarray(infos.changed)
                clock_k = np.asarray(infos.clock)
                rr, ww = np.nonzero(changed_k[: last + 1])
                history.extend(
                    zip(clock_k[rr, ww].tolist(), ww.tolist(), certs_k[rr, ww].tolist())
                )
            self._maybe_publish(state, rounds)
            if stop is not None:
                break
        # final flush: a last-chunk improvement between cadence points
        # still reaches the serving tier before run() returns
        self._maybe_publish(state, rounds, final=True)

        certs = np.asarray(state.certs)
        models = self.worker.export_models(state.worker)
        # counters are () scalars on the single-device engine and
        # (n_devices,) per-shard partials on the sharded one; np.sum
        # covers both (the per-shard reduction happens here, once)
        ictrl, dctrl = self._control_split()
        traffic = TrafficCounters.from_shards(
            sent=np.asarray(state.sent),
            accepted=np.asarray(state.accepted),
            discarded=np.asarray(state.discarded),
            payload_bytes=self._payload_bytes,
            sent_dcn=np.asarray(state.sent_dcn),
            evicted=np.asarray(state.evicted),
            control_bytes=(ictrl + dctrl) * rounds,
            dropped_injected=np.asarray(state.dropped_inj),
            corrupt_rejected=np.asarray(state.corrupt_rej),
        )
        # a join "happened" when its spare went live strictly after
        # round 0 and before the run ended (k=1 joins are full members
        # from the start, so a k=1 run reports 0 — matching the plain
        # run it is bit-identical to)
        jr = self._join_round_np
        workers_joined = int(np.sum((jr > 0) & (jr < rounds)))
        final_models = [
            jax.tree_util.tree_map(lambda a, i=i: a[i], models)
            for i in range(cfg.n_workers)
        ]
        ici_bytes, dcn_bytes = self._gossip_split()
        return SimResult.from_traffic(
            traffic,
            history=history,
            final_certificates=[float(c) for c in certs],
            final_models=final_models,
            sim_time=float(np.asarray(state.clock).max()),
            cost_units_total=float(np.sum(np.asarray(state.cost_total))),
            events_processed=rounds * cfg.n_workers,
            rounds=rounds,
            gossip_bytes_per_round=ici_bytes + dcn_bytes,
            gossip_bytes_per_round_ici=ici_bytes,
            gossip_bytes_per_round_dcn=dcn_bytes,
            gossip_mode=self._gossip_mode(),
            inflight_occupancy_peak=int(np.max(np.asarray(state.occ_peak))),
            control_bytes_per_round=ictrl + dctrl,
            control_plane=cfg.control_plane,
            inflight_capacity_selected=self._auto_selected,
            workers_joined=workers_joined,
        )

    def _gossip_split(self) -> tuple[int, int]:
        """(ICI, DCN) cross-device exchange footprint per round; the DCN
        leg is amortized over ``cross_pod_every_k``. (0, 0) on one
        device."""
        return 0, 0

    def _control_split(self) -> tuple[int, int]:
        """(ICI, DCN) CONTROL-plane sub-footprint of
        :meth:`_gossip_split` per round — the certificate/flag/id bytes
        as opposed to model payload bytes. (0, 0) on one device."""
        return 0, 0

    def _gossip_mode(self) -> str:
        """Mode label for SimResult; one device has no cross-device
        gossip, so the config knob is reported as inert."""
        return "dense"


def quantize_latency(
    base_latency: float,
    jitter: float,
    round_dt: float,
    n_workers: int,
    seed: int = 0,
) -> np.ndarray:
    """Quantize the simulator's continuous per-link latency model to an
    integer (W, W) round-delay matrix: ``delay = max(1, round(lat/dt))``.

    Jitter is drawn from the same U[0, jitter) distribution as the event
    sim, but sampled ONCE per link and frozen for the whole run (the
    engine's delay matrix is static), whereas the simulator redraws it
    per message — expect distributional differences under jitter > 0."""
    rng = np.random.default_rng(seed)
    lat = base_latency + rng.uniform(0.0, max(jitter, 0.0), size=(n_workers, n_workers))
    dt = max(round_dt, 1e-12)
    return np.maximum(np.rint(lat / dt), 1).astype(np.int32)


def make_engine(worker: BatchedTMSNWorker, config: EngineConfig) -> TMSNEngine:
    """Build the right engine for ``config.mesh``.

    ``mesh=None`` or a 1-device mesh falls back to the single-device
    :class:`TMSNEngine` (the sharded path would only add collective
    overhead); a multi-device mesh with a ``workers`` axis builds the
    shard-mapped :class:`~repro.core.engine_sharded.ShardedTMSNEngine` —
    single-tier on a ``("workers",)`` mesh, hierarchical two-tier on a
    ``("pod", "workers")`` mesh.
    """
    mesh = config.mesh
    if mesh is None or mesh.size == 1:
        return TMSNEngine(worker, config)
    if "workers" not in mesh.axis_names:
        raise ValueError(f"engine mesh needs a 'workers' axis, got {mesh.axis_names}")
    from repro.core.engine_sharded import ShardedTMSNEngine

    return ShardedTMSNEngine(worker, config)
