"""Mamba2 (SSD — state-space duality, arXiv:2405.21060).

Training path: the chunked SSD algorithm — within-chunk "attention"
term (the duality: a masked C@B^T matmul, MXU-friendly) plus an
inter-chunk recurrence carried by ``lax.scan``. Decode path: the O(1)
recurrent state update. Both share the same discretization so they are
numerically consistent (tested).

Recurrence (per head; state h in R^{N x P}):
    h_t = exp(-exp(a_log) * dt_t) * h_{t-1} + dt_t * B_t (x) x_t
    y_t = C_t . h_t + D * x_t
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import init_linear


def ssm_dims(cfg: ArchConfig) -> tuple[int, int, int, int]:
    """(d_inner, heads, head_dim, state)."""
    d_inner = cfg.ssm_expand * cfg.d_model
    P = cfg.ssm_head_dim
    H = d_inner // P
    return d_inner, H, P, cfg.ssm_state


def init_ssm(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    d_inner, H, P, N = ssm_dims(cfg)
    conv_ch = d_inner + 2 * N  # conv over [x, B, C]
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (gate), x, B, C, dt]
        "in_proj": init_linear(ks[0], cfg.d_model, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch)) * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "a_log": jnp.zeros((H,), jnp.float32),  # A = -exp(a_log) = -1
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "norm": jnp.zeros((d_inner,), dtype),
        "out_proj": init_linear(ks[2], d_inner, cfg.d_model, dtype),
    }


def _split_proj(proj: jnp.ndarray, cfg: ArchConfig):
    d_inner, H, P, N = ssm_dims(cfg)
    z = proj[..., :d_inner]
    xBC = proj[..., d_inner : 2 * d_inner + 2 * N]
    dt = proj[..., 2 * d_inner + 2 * N :]
    return z, xBC, dt


def _causal_conv_full(xBC: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv over (b, s, ch) with taps (k, ch)."""
    k = w.shape[0]
    pad = jnp.pad(xBC, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(k)
    )
    return jax.nn.silu(out + b[None, None, :])


def ssd_full(
    params: dict, x: jnp.ndarray, cfg: ArchConfig
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence Mamba2 block. Returns (out, (state, conv_tail)) so
    prefill can seed the decode cache."""
    from repro.models.layers import rms_norm

    bsz, s, _ = x.shape
    d_inner, H, P, N = ssm_dims(cfg)
    Q = min(cfg.ssm_chunk, s)
    assert s % Q == 0, "seq must divide into SSD chunks"
    nc = s // Q

    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xBC, dt_raw = _split_proj(proj, cfg)
    xBC = _causal_conv_full(xBC, params["conv_w"].astype(x.dtype), params["conv_b"].astype(x.dtype))
    xs = xBC[..., :d_inner].reshape(bsz, s, H, P)
    B = xBC[..., d_inner : d_inner + N]  # (b, s, N) single group
    C = xBC[..., d_inner + N :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + params["dt_bias"])  # (b,s,H)
    a = -jnp.exp(params["a_log"])  # (H,)
    log_da = dt * a[None, None, :]  # log decay, (b,s,H) (negative)

    # chunk views
    xs_c = xs.reshape(bsz, nc, Q, H, P)
    B_c = B.reshape(bsz, nc, Q, N).astype(jnp.float32)
    C_c = C.reshape(bsz, nc, Q, N).astype(jnp.float32)
    dt_c = dt.reshape(bsz, nc, Q, H)
    ld_c = log_da.reshape(bsz, nc, Q, H)
    cum = jnp.cumsum(ld_c, axis=2)  # l_i per chunk

    # intra-chunk (the "duality" matmul): M_ij = exp(l_i - l_j), i >= j
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,Q,Q,H)
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :, None]
    M = jnp.where(causal, jnp.exp(diff), 0.0)
    G = jnp.einsum("bcqn,bcsn->bcqs", C_c, B_c)  # (b,nc,Q,Q)
    W = G[..., None] * M  # (b,nc,Q,Q,H)
    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", W, xdt)

    # chunk-final states: S_c = sum_j exp(l_Q - l_j) dt_j B_j (x) x_j
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqn,bcqhp->bchnp", decay_to_end, B_c, xdt)
    if cfg.act_dp is not None:
        # keep the state H-sharded over `model`: otherwise the H-sharded
        # decay/xdt operands get all-gathered against the N-sharded B
        # (8 x 1.07GB/step measured on zamba2 — §Perf hillclimb B)
        S_c = jax.lax.with_sharding_constraint(
            S_c, jax.sharding.PartitionSpec(cfg.act_dp, None, "model", None, None)
        )
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,H)

    def scan_fn(carry, inp):
        s_chunk, decay = inp  # (b,H,N,P), (b,H)
        new = carry * decay[:, :, None, None] + s_chunk
        return new, carry  # emit state BEFORE this chunk

    init_state = jnp.zeros((bsz, H, N, P), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn,
        init_state,
        (S_c.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (b,nc,H,N,P)

    # inter-chunk: y_i += C_i . (exp(l_i) * S_prev)
    decay_in = jnp.exp(cum)  # (b,nc,Q,H)
    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", C_c, prev_states, decay_in)

    y = (y_intra + y_inter).reshape(bsz, s, H, P)
    y = y + xs.astype(jnp.float32) * params["D"][None, None, :, None]
    y = y.reshape(bsz, s, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))

    conv_tail = xBC_tail(x, params, cfg)
    return out, (final_state, conv_tail)


def xBC_tail(x: jnp.ndarray, params: dict, cfg: ArchConfig) -> jnp.ndarray:
    """Last (conv_width-1) pre-conv channels — the decode conv state."""
    d_inner, H, P, N = ssm_dims(cfg)
    k = cfg.ssm_conv_width
    proj = jnp.einsum("bsd,de->bse", x[:, -(k - 1):, :], params["in_proj"].astype(x.dtype))
    _, xBC, _ = _split_proj(proj, cfg)
    return xBC  # (b, k-1, conv_ch)


def ssd_decode(
    params: dict,
    x: jnp.ndarray,  # (b, 1, d)
    state: jnp.ndarray,  # (b, H, N, P) f32
    conv_state: jnp.ndarray,  # (b, k-1, conv_ch)
    cfg: ArchConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token recurrent step. Returns (out, state', conv_state')."""
    from repro.models.layers import rms_norm

    bsz = x.shape[0]
    d_inner, H, P, N = ssm_dims(cfg)
    proj = jnp.einsum("bsd,de->bse", x, params["in_proj"].astype(x.dtype))
    z, xBC_new, dt_raw = _split_proj(proj, cfg)

    # causal conv over the rolling window [conv_state, new]
    window = jnp.concatenate([conv_state.astype(x.dtype), xBC_new], axis=1)  # (b, k, ch)
    w = params["conv_w"].astype(x.dtype)
    conv = jnp.sum(window * w[None, :, :], axis=1) + params["conv_b"].astype(x.dtype)
    xBC = jax.nn.silu(conv)  # (b, ch)
    xs = xBC[:, :d_inner].reshape(bsz, H, P).astype(jnp.float32)
    B = xBC[:, d_inner : d_inner + N].astype(jnp.float32)
    C = xBC[:, d_inner + N :].astype(jnp.float32)

    dt = jax.nn.softplus(dt_raw[:, 0, :].astype(jnp.float32) + params["dt_bias"])  # (b,H)
    da = jnp.exp(dt * -jnp.exp(params["a_log"]))  # (b,H)
    state = state * da[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, B, xs
    )
    y = jnp.einsum("bn,bhnp->bhp", C, state) + xs * params["D"][None, :, None]
    y = y.reshape(bsz, 1, d_inner).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z), params["norm"], cfg.norm_eps)
    out = jnp.einsum("bse,ed->bsd", y, params["out_proj"].astype(x.dtype))
    conv_state = window[:, 1:, :]
    return out, state, conv_state


def ssd_reference(
    params: dict, x: jnp.ndarray, cfg: ArchConfig
) -> jnp.ndarray:
    """Step-by-step recurrence oracle (slow; tests only)."""
    bsz, s, _ = x.shape
    d_inner, H, P, N = ssm_dims(cfg)
    k = cfg.ssm_conv_width
    state = jnp.zeros((bsz, H, N, P), jnp.float32)
    conv_ch = d_inner + 2 * N
    conv_state = jnp.zeros((bsz, k - 1, conv_ch), x.dtype)
    outs = []
    for i in range(s):
        o, state, conv_state = ssd_decode(params, x[:, i : i + 1, :], state, conv_state, cfg)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)
