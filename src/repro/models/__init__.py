"""Transformer/SSM/MoE model zoo for the assigned architectures.

Pure-functional JAX: ``init_params(cfg, key)`` builds a param pytree,
``loss_fn`` / ``prefill`` / ``decode_step`` apply it. Layer stacks are
``lax.scan`` over stacked params (one scan per homogeneous segment) so
the HLO stays small enough to lower 61-layer 671B-param graphs.
"""

from repro.models.config import ArchConfig, LayerSpec, layer_segments
from repro.models.model import (
    init_params,
    loss_fn,
    prefill,
    decode_step,
    init_cache,
    param_count,
)

__all__ = [
    "ArchConfig",
    "LayerSpec",
    "layer_segments",
    "init_params",
    "loss_fn",
    "prefill",
    "decode_step",
    "init_cache",
    "param_count",
]
