"""Architecture config schema + the layer-pattern machinery.

An ``ArchConfig`` fully determines a model. Heterogeneous stacks
(gemma3's 5 local : 1 global, deepseek's first-k-dense, zamba2's shared
attention) are expressed as *segments*: a repeating unit of
``LayerSpec``s scanned ``repeats`` times. Each unit-position gets its
own stacked parameters (leading dim = repeats); ``shared_attn`` layers
reference one un-stacked param set (true weight sharing, as in Zamba2).
"""

from __future__ import annotations

import dataclasses
from typing import Literal

LayerKind = Literal["attn", "moe", "ssm", "shared_attn"]


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    kind: LayerKind = "attn"
    window: int | None = None  # sliding-window size (None = full attention)
    cross_attention: bool = False  # decoder layer with cross-attn (enc-dec)


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab: int
    citation: str = ""

    head_dim: int | None = None  # default d_model // num_heads
    attention: str = "gqa"  # gqa | mla
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    mlp_gated: bool = True  # SwiGLU (True) vs GELU 2-matrix MLP (False)

    # sliding-window pattern (gemma3): every `local_ratio` local layers
    # followed by 1 global layer; window applies to local layers.
    sliding_window: int | None = None
    local_ratio: int = 0

    # MoE
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_d_ff: int | None = None
    first_k_dense: int = 0
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.001

    # MLA (DeepSeek-V3)
    q_lora_rank: int = 0
    kv_lora_rank: int = 0
    qk_nope_head_dim: int = 0
    qk_rope_head_dim: int = 0
    v_head_dim: int = 0

    # MTP (DeepSeek-V3 multi-token prediction) — extra predict depth
    mtp_depth: int = 0

    # SSM (Mamba2 SSD)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # hybrid (zamba2): one shared attention block applied every
    # `shared_attn_every` ssm layers
    shared_attn_every: int = 0

    # enc-dec (whisper)
    encoder_layers: int = 0

    # modality frontend stub (audio frames / vision patches)
    frontend: str | None = None  # "audio" | "vision"
    frontend_len: int = 0
    frontend_dim: int = 0

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    remat: bool = True

    # capabilities
    supports_long_decode: bool = False  # sub-quadratic decode at 500k

    # --- §Perf hillclimb knobs (baseline = all off; see EXPERIMENTS.md) ---
    #: re-anchor activation sharding at every layer-scan step (fixes
    #: batch-sharding loss inside while bodies -> replicated-batch temps)
    act_dp: tuple[str, ...] | None = None
    #: pad embedding/lm_head vocab to a multiple (0 = off); enables
    #: vocab-dim sharding for vocabs not divisible by the mesh axis
    vocab_pad_multiple: int = 0
    #: ring-buffer KV caches sized to the window for sliding-window
    #: layers (512x capacity cut on gemma3 long_500k — §Perf)
    windowed_cache: bool = False

    # analysis-mode knobs (dry-run cost extrapolation; see launch/dryrun.py):
    # unroll segment scans so XLA cost analysis sees every layer, and
    # override per-segment repeat counts (decoder, then encoder).
    scan_unroll: bool = False
    reps_override: tuple[int, ...] | None = None
    enc_reps_override: tuple[int, ...] | None = None

    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    def expert_ff(self) -> int:
        return self.moe_d_ff or self.d_ff

    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def padded_vocab(self) -> int:
        if not self.vocab_pad_multiple:
            return self.vocab
        m = self.vocab_pad_multiple
        return -(-self.vocab // m) * m


def layer_segments(cfg: ArchConfig) -> list[tuple[list[LayerSpec], int]]:
    """Decoder-stack segments: list of (unit, repeats).

    The unit is scanned `repeats` times; ``sum(len(unit)*reps) ==
    cfg.num_layers`` counting only parameterized-per-layer specs
    (``shared_attn`` applications are extra, weight-shared).
    """
    segs: list[tuple[list[LayerSpec], int]] = []
    segs = _base_segments(cfg)
    if cfg.reps_override is not None:
        assert len(cfg.reps_override) == len(segs), (cfg.name, cfg.reps_override, len(segs))
        segs = [(u, r) for (u, _), r in zip(segs, cfg.reps_override)]
    return segs


def _base_segments(cfg: ArchConfig) -> list[tuple[list[LayerSpec], int]]:
    segs: list[tuple[list[LayerSpec], int]] = []
    if cfg.arch_type == "ssm":
        segs.append(([LayerSpec(kind="ssm")], cfg.num_layers))
    elif cfg.arch_type == "hybrid":
        k = cfg.shared_attn_every or 6
        full, rem = divmod(cfg.num_layers, k)
        if full:
            unit = [LayerSpec(kind="ssm")] * k + [LayerSpec(kind="shared_attn")]
            segs.append((unit, full))
        if rem:
            segs.append(([LayerSpec(kind="ssm")] * rem, 1))
    elif cfg.num_experts > 0:
        if cfg.first_k_dense:
            segs.append(([LayerSpec(kind="attn")], cfg.first_k_dense))
        segs.append(([LayerSpec(kind="moe")], cfg.num_layers - cfg.first_k_dense))
    elif cfg.local_ratio:
        unit_len = cfg.local_ratio + 1
        full, rem = divmod(cfg.num_layers, unit_len)
        unit = [LayerSpec(kind="attn", window=cfg.sliding_window)] * cfg.local_ratio + [
            LayerSpec(kind="attn", window=None)
        ]
        if full:
            segs.append((unit, full))
        if rem:
            segs.append(([LayerSpec(kind="attn", window=cfg.sliding_window)] * rem, 1))
    else:
        cross = cfg.is_encdec()
        segs.append(([LayerSpec(kind="attn", cross_attention=cross)], cfg.num_layers))
    return segs


def encoder_segments(cfg: ArchConfig) -> list[tuple[list[LayerSpec], int]]:
    if not cfg.is_encdec():
        return []
    reps = cfg.encoder_layers
    if cfg.enc_reps_override is not None:
        reps = cfg.enc_reps_override[0]
    return [([LayerSpec(kind="attn")], reps)]


def validate(cfg: ArchConfig) -> None:
    assert cfg.num_heads % max(cfg.num_kv_heads, 1) == 0 or cfg.attention == "mla"
    if cfg.reps_override is None:
        n_param_layers = sum(
            reps * sum(1 for s in unit if s.kind != "shared_attn")
            for unit, reps in layer_segments(cfg)
        )
        assert n_param_layers == cfg.num_layers, (cfg.name, n_param_layers, cfg.num_layers)
    if cfg.num_experts:
        assert cfg.num_experts_per_tok > 0
    if cfg.attention == "mla":
        assert cfg.kv_lora_rank > 0 and cfg.qk_rope_head_dim > 0
