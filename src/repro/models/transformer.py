"""Blocks + segment-scanned stacks.

A *segment* is a repeating unit of layers scanned over its repeat count
(``lax.scan`` keeps the HLO size O(unique layers), which is what lets a
61-layer MoE or 64-layer Grok lower quickly). ``shared_attn`` layers
(Zamba2) close over one un-stacked param set — true weight sharing —
while still getting a per-application KV cache slot.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.config import ArchConfig, LayerSpec
from repro.models.layers import apply_mlp, init_mlp, init_rms_norm, rms_norm
from repro.models.moe import apply_moe, init_moe
from repro.models.ssm import init_ssm, ssd_decode, ssd_full


# ----------------------------------------------------------------------------
# per-layer init
# ----------------------------------------------------------------------------


def init_layer(key: jax.Array, spec: LayerSpec, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 6)
    if spec.kind == "ssm":
        return {"ln1": init_rms_norm(cfg.d_model, dtype), "ssm": init_ssm(ks[0], cfg, dtype)}
    p: dict[str, Any] = {"ln1": init_rms_norm(cfg.d_model, dtype)}
    if cfg.attention == "mla" and spec.kind in ("attn", "moe"):
        p["attn"] = attn.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = attn.init_gqa(ks[0], cfg, dtype)
    if spec.cross_attention:
        p["ln_x"] = init_rms_norm(cfg.d_model, dtype)
        p["cross"] = attn.init_cross(ks[1], cfg, dtype)
    p["ln2"] = init_rms_norm(cfg.d_model, dtype)
    if spec.kind == "moe":
        p["moe"] = init_moe(ks[2], cfg, dtype)
    else:
        p["mlp"] = init_mlp(ks[2], cfg.d_model, cfg.d_ff, dtype, gated=cfg.mlp_gated)
    return p


def init_shared_attn(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    """Zamba2's single shared transformer block."""
    return init_layer(key, LayerSpec(kind="attn"), cfg, dtype)


# ----------------------------------------------------------------------------
# per-layer apply — full sequence (training / prefill)
# ----------------------------------------------------------------------------


def apply_layer_full(
    p: dict,
    spec: LayerSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    enc_out: jnp.ndarray | None,
):
    """Returns (x', cache_entry, aux)."""
    aux = jnp.zeros((), jnp.float32)
    if spec.kind == "ssm":
        h, (state, conv_tail) = ssd_full(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), cfg)
        return x + h, (state, conv_tail), aux

    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla" and spec.kind in ("attn", "moe"):
        h, (ckv, krope) = attn.mla_full(p["attn"], h_in, positions, cfg)
        cache = (ckv, krope)
    else:
        h, (k, v) = attn.gqa_full(p["attn"], h_in, positions, cfg, window=spec.window)
        cache = (k, v)
    x = x + h
    if spec.cross_attention:
        assert enc_out is not None
        ck, cv = attn.cross_kv(p["cross"], enc_out, cfg)
        x = x + attn.cross_attend(p["cross"], rms_norm(x, p["ln_x"], cfg.norm_eps), ck, cv, cfg)
        cache = cache + (ck, cv)
    h2_in = rms_norm(x, p["ln2"], cfg.norm_eps)
    if spec.kind == "moe":
        h2, aux = apply_moe(p["moe"], h2_in, cfg)
    else:
        h2 = apply_mlp(p["mlp"], h2_in)
    return x + h2, cache, aux


# ----------------------------------------------------------------------------
# per-layer apply — one-token decode against a cache entry
# ----------------------------------------------------------------------------


def apply_layer_decode(
    p: dict,
    spec: LayerSpec,
    cfg: ArchConfig,
    x: jnp.ndarray,
    cache: tuple,
    pos: jnp.ndarray,
):
    if spec.kind == "ssm":
        state, conv = cache
        h, state, conv = ssd_decode(p["ssm"], rms_norm(x, p["ln1"], cfg.norm_eps), state, conv, cfg)
        return x + h, (state, conv)

    h_in = rms_norm(x, p["ln1"], cfg.norm_eps)
    if cfg.attention == "mla" and spec.kind in ("attn", "moe"):
        ckv, krope = cache[:2]
        h, ckv, krope = attn.mla_decode(p["attn"], h_in, ckv, krope, pos, cfg)
        new_cache = (ckv, krope) + cache[2:]
    else:
        ck_, cv_ = cache[:2]
        h, ck_, cv_ = attn.gqa_decode(p["attn"], h_in, ck_, cv_, pos, cfg, window=spec.window)
        new_cache = (ck_, cv_) + cache[2:]
    x = x + h
    if spec.cross_attention:
        enc_k, enc_v = cache[2], cache[3]
        x = x + attn.cross_attend(
            p["cross"], rms_norm(x, p["ln_x"], cfg.norm_eps), enc_k, enc_v, cfg
        )
    h2_in = rms_norm(x, p["ln2"], cfg.norm_eps)
    if spec.kind == "moe":
        h2, _ = apply_moe(p["moe"], h2_in, cfg)
    else:
        h2 = apply_mlp(p["mlp"], h2_in)
    return x + h2, new_cache


# ----------------------------------------------------------------------------
# segment machinery
# ----------------------------------------------------------------------------


def init_segments(
    key: jax.Array, segments: list[tuple[list[LayerSpec], int]], cfg: ArchConfig, dtype
) -> list[list[Any]]:
    """Per segment: a list over unit positions of param trees stacked
    over repeats (leading axis). ``shared_attn`` positions hold None
    (their weights live in params['shared_attn'])."""
    out = []
    for si, (unit, reps) in enumerate(segments):
        seg_params = []
        for li, spec in enumerate(unit):
            if spec.kind == "shared_attn":
                seg_params.append(None)
                continue
            keys = jax.random.split(jax.random.fold_in(key, si * 97 + li), reps)
            stacked = jax.vmap(lambda k: init_layer(k, spec, cfg, dtype))(keys)
            seg_params.append(stacked)
        out.append(seg_params)
    return out


def _scan_segment_full(
    seg_params: list,
    unit: list[LayerSpec],
    reps: int,
    cfg: ArchConfig,
    shared_params: dict | None,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    enc_out: jnp.ndarray | None,
    collect_cache: bool,
):
    """Scan one segment over its repeats (full-sequence mode)."""

    def body(carry, xs):
        h, aux = carry
        if cfg.act_dp is not None:
            h = jax.lax.with_sharding_constraint(
                h, jax.sharding.PartitionSpec(cfg.act_dp, None, None)
            )
        caches = []
        for li, spec in enumerate(unit):
            if spec.kind == "shared_attn":
                h2, cache, a = apply_layer_full(
                    shared_params, LayerSpec(kind="attn"), cfg, h, positions, enc_out
                )
            else:
                h2, cache, a = apply_layer_full(xs[li], spec, cfg, h, positions, enc_out)
            h = h2
            aux = aux + a
            caches.append(cache if collect_cache else None)
        return (h, aux), tuple(caches)

    if cfg.remat:
        body = jax.checkpoint(body)
    xs = tuple(seg_params)
    (x, aux), caches = jax.lax.scan(
        body, (x, jnp.zeros((), jnp.float32)), xs, length=reps,
        unroll=reps if cfg.scan_unroll else 1,
    )
    return x, aux, caches


def _scan_segment_decode(
    seg_params: list,
    unit: list[LayerSpec],
    reps: int,
    cfg: ArchConfig,
    shared_params: dict | None,
    x: jnp.ndarray,
    seg_cache: tuple,
    pos: jnp.ndarray,
):
    def body(h, xs):
        params_and_cache = xs
        new_caches = []
        for li, spec in enumerate(unit):
            p_li, c_li = params_and_cache[li]
            if spec.kind == "shared_attn":
                h, nc = apply_layer_decode(shared_params, LayerSpec(kind="attn"), cfg, h, c_li, pos)
            else:
                h, nc = apply_layer_decode(p_li, spec, cfg, h, c_li, pos)
            new_caches.append(nc)
        return h, tuple(new_caches)

    xs = tuple((seg_params[li], seg_cache[li]) for li in range(len(unit)))
    x, new_cache = jax.lax.scan(
        body, x, xs, length=reps, unroll=reps if cfg.scan_unroll else 1
    )
    return x, new_cache


def forward_stack(
    params_segments: list,
    segments: list[tuple[list[LayerSpec], int]],
    cfg: ArchConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    shared_params: dict | None = None,
    enc_out: jnp.ndarray | None = None,
    collect_cache: bool = False,
):
    """Full-sequence pass over all segments.

    Returns (x, aux_total, caches) — caches is a list aligned with
    segments (None entries when collect_cache=False).
    """
    aux_total = jnp.zeros((), jnp.float32)
    caches = []
    for (unit, reps), seg_params in zip(segments, params_segments):
        x, aux, cache = _scan_segment_full(
            seg_params, unit, reps, cfg, shared_params, x, positions, enc_out, collect_cache
        )
        aux_total = aux_total + aux
        caches.append(cache)
    return x, aux_total, caches


def decode_stack(
    params_segments: list,
    segments: list[tuple[list[LayerSpec], int]],
    cfg: ArchConfig,
    x: jnp.ndarray,
    caches: list,
    pos: jnp.ndarray,
    shared_params: dict | None = None,
):
    new_caches = []
    for (unit, reps), seg_params, seg_cache in zip(segments, params_segments, caches):
        x, nc = _scan_segment_decode(
            seg_params, unit, reps, cfg, shared_params, x, seg_cache, pos
        )
        new_caches.append(nc)
    return x, new_caches
