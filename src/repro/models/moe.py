"""Mixture-of-Experts with capacity-based dispatch (DeepSeek-V3 /
Grok-1 style: shared + routed experts, top-k softmax gate).

Dispatch uses the one-hot + cumsum position scheme (the standard JAX
MoE formulation): token slots are scattered into a dense
(experts, capacity, d) buffer, expert FFNs run as a single batched
einsum with the expert dim sharded over the ``model`` mesh axis
(expert parallelism), and results are combined back with the gate
weights. Tokens over capacity are dropped (their residual passes
through) — capacity_factor controls the drop rate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import init_linear, init_mlp


def init_moe(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    E, d, f = cfg.num_experts, cfg.d_model, cfg.expert_ff()
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    p = {
        "router": init_linear(k1, d, E, jnp.float32),  # router kept f32
        "gate": (jax.random.normal(k2, (E, d, f)) * d ** -0.5).astype(dtype),
        "up": (jax.random.normal(k3, (E, d, f)) * d ** -0.5).astype(dtype),
        "down": (jax.random.normal(k4, (E, f, d)) * f ** -0.5).astype(dtype),
    }
    if cfg.num_shared_experts:
        p["shared"] = init_mlp(k5, d, f * cfg.num_shared_experts, dtype)
    return p


def moe_capacity(cfg: ArchConfig, num_tokens: int) -> int:
    cap = int(num_tokens * cfg.num_experts_per_tok * cfg.capacity_factor / cfg.num_experts)
    return max(8, -(-cap // 8) * 8)  # round up to a multiple of 8


def apply_moe(
    params: dict, x: jnp.ndarray, cfg: ArchConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (out (b,s,d), aux_loss ()). Router runs in f32."""
    b, s, d = x.shape
    t = b * s
    k = cfg.num_experts_per_tok
    E = cfg.num_experts
    C = moe_capacity(cfg, t)
    xt = x.reshape(t, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)  # (t, E)
    topw, topi = jax.lax.top_k(probs, k)  # (t, k)
    topw = topw / jnp.maximum(jnp.sum(topw, axis=-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)  # mean router prob per expert
    assign = jax.nn.one_hot(topi[:, 0], E, dtype=jnp.float32)  # top-1 fraction
    fe = jnp.mean(assign, axis=0)
    aux = E * jnp.sum(fe * me) * cfg.router_aux_coef

    # slot layout: slot i covers token i//k, choice i%k
    sid = topi.reshape(t * k)  # expert id per slot
    onehot = jax.nn.one_hot(sid, E, dtype=jnp.int32)  # (t*k, E)
    pos_in_e = jnp.cumsum(onehot, axis=0) * onehot
    pos = jnp.sum(pos_in_e, axis=-1) - 1  # (t*k,) 0-based position within expert
    keep = (pos >= 0) & (pos < C)
    pos_c = jnp.clip(pos, 0, C - 1)

    tok = jnp.arange(t * k) // k
    slot_x = xt[tok] * keep[:, None].astype(xt.dtype)  # (t*k, d)
    buf = jnp.zeros((E, C, d), xt.dtype).at[sid, pos_c].add(slot_x)

    # expert FFN (SwiGLU), expert dim sharded over `model`
    g = jnp.einsum("ecd,edf->ecf", buf, params["gate"].astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, params["up"].astype(buf.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, params["down"].astype(buf.dtype))

    out_slots = y[sid, pos_c] * keep[:, None].astype(y.dtype)
    out_slots = out_slots * topw.reshape(t * k, 1).astype(y.dtype)
    out = jnp.sum(out_slots.reshape(t, k, d), axis=1)

    if "shared" in params:
        from repro.models.layers import apply_mlp

        out = out + apply_mlp(params["shared"], xt)
    return out.reshape(b, s, d), aux
