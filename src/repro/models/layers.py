"""Shared low-level layers: RMSNorm, SwiGLU MLP, RoPE, embeddings."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def init_rms_norm(d: int, dtype) -> jnp.ndarray:
    return jnp.zeros((d,), dtype)


def swiglu(x: jnp.ndarray, w_gate: jnp.ndarray, w_up: jnp.ndarray, w_down: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down.astype(x.dtype))


def init_mlp(key: jax.Array, d: int, f: int, dtype, gated: bool = True) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    s_in = d ** -0.5
    s_out = f ** -0.5
    p = {
        "up": (jax.random.normal(k2, (d, f)) * s_in).astype(dtype),
        "down": (jax.random.normal(k3, (f, d)) * s_out).astype(dtype),
    }
    if gated:
        p["gate"] = (jax.random.normal(k1, (d, f)) * s_in).astype(dtype)
    return p


def apply_mlp(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "gate" in params:
        return swiglu(x, params["gate"], params["up"], params["down"])
    u = jnp.einsum("...d,df->...f", x, params["up"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(u), params["down"].astype(x.dtype))


def rope_freqs(positions: jnp.ndarray, dim: int, theta: float) -> tuple[jnp.ndarray, jnp.ndarray]:
    """positions (...,) -> cos/sin of shape (..., dim//2)."""
    half = dim // 2
    inv = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray) -> jnp.ndarray:
    """x (..., s, h, dim); cos/sin (..., s, dim//2) broadcast over heads."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


def init_embedding(key: jax.Array, vocab: int, d: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


def init_linear(key: jax.Array, d_in: int, d_out: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (d_in, d_out)) * d_in ** -0.5).astype(dtype)


def softmax_cross_entropy(
    logits: jnp.ndarray, labels: jnp.ndarray, mask: jnp.ndarray
) -> jnp.ndarray:
    """Mean next-token loss. logits (b,s,V) f32, labels (b,s) int, mask (b,s)."""
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
