"""Top-level model API: init_params / loss_fn / prefill / decode_step.

Handles the whole zoo uniformly:
  * decoder-only LMs (dense / MoE / SSM / hybrid),
  * enc-dec (whisper): the encoder consumes stub frontend embeddings,
    the decoder cross-attends,
  * VLM (phi-3-vision): stub patch embeddings are *spliced into* the
    first ``frontend_len`` sequence positions through a projector
    (multimodal interleave without changing the global (b, s) shape),
  * DeepSeek MTP: an auxiliary next-next-token head (simplified MTP —
    shared trunk, extra projection; DESIGN.md notes the deviation from
    the paper's full extra-block variant).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig, encoder_segments, layer_segments, validate
from repro.models.layers import (
    init_embedding,
    init_linear,
    init_rms_norm,
    rms_norm,
    softmax_cross_entropy,
)
from repro.models.ssm import ssm_dims
from repro.models.transformer import (
    decode_stack,
    forward_stack,
    init_segments,
    init_shared_attn,
)


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ----------------------------------------------------------------------------
# init
# ----------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key: jax.Array) -> dict:
    validate(cfg)
    dtype = _dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    params: dict[str, Any] = {
        "embed": init_embedding(ks[0], cfg.padded_vocab(), cfg.d_model, dtype),
        "final_norm": init_rms_norm(cfg.d_model, dtype),
        "decoder": init_segments(ks[1], layer_segments(cfg), cfg, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = init_linear(ks[2], cfg.d_model, cfg.padded_vocab(), dtype)
    if cfg.arch_type == "hybrid":
        params["shared_attn"] = init_shared_attn(ks[3], cfg, dtype)
    if cfg.is_encdec():
        params["encoder"] = init_segments(ks[4], encoder_segments(cfg), cfg, dtype)
        params["enc_norm"] = init_rms_norm(cfg.d_model, dtype)
    if cfg.frontend is not None:
        params["frontend_proj"] = init_linear(ks[5], cfg.frontend_dim, cfg.d_model, dtype)
    if cfg.mtp_depth:
        params["mtp_head"] = init_linear(ks[6], cfg.d_model, cfg.d_model, dtype)
    return params


def param_count(params) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(params))


# ----------------------------------------------------------------------------
# embedding / frontend splicing / encoder
# ----------------------------------------------------------------------------


def _embed(params, cfg: ArchConfig, tokens: jnp.ndarray, batch: dict) -> jnp.ndarray:
    cdt = _dtype(cfg.compute_dtype)
    x = params["embed"][tokens].astype(cdt)
    if cfg.frontend == "vision" and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(cdt)
        proj = jnp.einsum("bfe,ed->bfd", fe, params["frontend_proj"].astype(cdt))
        s = tokens.shape[1]
        f = proj.shape[1]
        if f < s:
            pad = jnp.zeros((tokens.shape[0], s - f, cfg.d_model), cdt)
            proj_full = jnp.concatenate([proj, pad], axis=1)
        else:
            proj_full = proj[:, :s, :]
        is_patch = (jnp.arange(s) < f)[None, :, None]
        x = jnp.where(is_patch, proj_full, x)
    return x


def _encode(params, cfg: ArchConfig, batch: dict) -> jnp.ndarray:
    """Whisper-style encoder over stub audio frame embeddings."""
    cdt = _dtype(cfg.compute_dtype)
    fe = batch["frontend_embeds"].astype(cdt)
    x = jnp.einsum("bfe,ed->bfd", fe, params["frontend_proj"].astype(cdt))
    positions = jnp.broadcast_to(jnp.arange(x.shape[1], dtype=jnp.int32), x.shape[:2])
    # bidirectional: encoder layers use full attention; our gqa_full is
    # causal, which for an encoder stub costs little fidelity — noted in
    # DESIGN.md (the paper's technique does not touch the encoder).
    x, _, _ = forward_stack(params["encoder"], encoder_segments(cfg), cfg, x, positions)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _logits(params, cfg: ArchConfig, x: jnp.ndarray) -> jnp.ndarray:
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)).astype(jnp.float32)
    vp = cfg.padded_vocab()
    if vp != cfg.vocab:
        # mask padded columns so CE logsumexp and argmax are exact
        pad_mask = (jnp.arange(vp) >= cfg.vocab) * -1e30
        logits = logits + pad_mask[None, None, :]
    return logits


# ----------------------------------------------------------------------------
# training loss
# ----------------------------------------------------------------------------


def loss_fn(params, cfg: ArchConfig, batch: dict) -> tuple[jnp.ndarray, dict]:
    tokens, labels, mask = batch["tokens"], batch["labels"], batch["mask"]
    x = _embed(params, cfg, tokens, batch)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape)
    enc_out = _encode(params, cfg, batch) if cfg.is_encdec() else None
    x, aux, _ = forward_stack(
        params["decoder"], layer_segments(cfg), cfg, x, positions,
        shared_params=params.get("shared_attn"), enc_out=enc_out,
    )
    logits = _logits(params, cfg, x)
    loss = softmax_cross_entropy(logits, labels, mask)
    metrics = {"ce_loss": loss, "aux_loss": aux}
    if cfg.mtp_depth:
        # simplified multi-token prediction: predict t+2 from a projected
        # trunk state; averaged into the loss at 0.3 weight (DeepSeek-V3
        # uses lambda=0.3)
        h2 = jnp.einsum("bsd,de->bse", x, params["mtp_head"].astype(x.dtype))
        logits2 = _logits(params, cfg, h2)
        labels2 = jnp.concatenate([labels[:, 1:], labels[:, :1]], axis=1)
        mask2 = mask.at[:, -1:].set(0.0)
        mtp = softmax_cross_entropy(logits2, labels2, mask2)
        metrics["mtp_loss"] = mtp
        loss = loss + 0.3 * mtp
    loss = loss + aux
    metrics["loss"] = loss
    return loss, metrics


# ----------------------------------------------------------------------------
# serving: cache init / prefill / decode
# ----------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, enc_len: int = 0) -> list:
    """Zeroed decode caches matching the decode_stack layout."""
    cdt = _dtype(cfg.compute_dtype)
    hd = cfg.hd()
    caches = []
    for unit, reps in layer_segments(cfg):
        seg = []
        for spec in unit:
            if spec.kind == "ssm":
                d_inner, H, P, N = ssm_dims(cfg)
                conv_ch = d_inner + 2 * N
                seg.append(
                    (
                        jnp.zeros((reps, batch, H, N, P), jnp.float32),
                        jnp.zeros((reps, batch, cfg.ssm_conv_width - 1, conv_ch), cdt),
                    )
                )
            elif cfg.attention == "mla":
                entry = (
                    jnp.zeros((reps, batch, max_len, cfg.kv_lora_rank), cdt),
                    jnp.zeros((reps, batch, max_len, cfg.qk_rope_head_dim), cdt),
                )
                seg.append(entry)
            else:
                # sliding-window layers only ever read back `window`
                # positions; with cfg.windowed_cache they get a ring
                # buffer of exactly that size (baseline: full length).
                s_buf = max_len
                if cfg.windowed_cache and spec.window:
                    s_buf = min(spec.window, max_len)
                entry = (
                    jnp.zeros((reps, batch, s_buf, cfg.num_kv_heads, hd), cdt),
                    jnp.zeros((reps, batch, s_buf, cfg.num_kv_heads, hd), cdt),
                )
                if spec.cross_attention:
                    entry = entry + (
                        jnp.zeros((reps, batch, enc_len, cfg.num_kv_heads, hd), cdt),
                        jnp.zeros((reps, batch, enc_len, cfg.num_kv_heads, hd), cdt),
                    )
                seg.append(entry)
        caches.append(tuple(seg))
    return caches


def prefill(params, cfg: ArchConfig, batch: dict) -> tuple[jnp.ndarray, list]:
    """Process the prompt; returns (last-position logits, prefill caches
    sized to the prompt — the serving layer re-buffers into max_len)."""
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens, batch)
    positions = jnp.broadcast_to(jnp.arange(tokens.shape[1], dtype=jnp.int32), tokens.shape)
    enc_out = _encode(params, cfg, batch) if cfg.is_encdec() else None
    x, _, caches = forward_stack(
        params["decoder"], layer_segments(cfg), cfg, x, positions,
        shared_params=params.get("shared_attn"), enc_out=enc_out, collect_cache=True,
    )
    logits = _logits(params, cfg, x[:, -1:, :])
    return logits, caches


def decode_step(
    params, cfg: ArchConfig, token: jnp.ndarray, caches: list, pos: jnp.ndarray, batch: dict | None = None
) -> tuple[jnp.ndarray, list]:
    """One-token decode. token (b, 1) int32; pos is the cache write
    index — () int32 for lockstep batches, or (b,) int32 for
    continuous batching (each row at its own depth)."""
    x = params["embed"][token].astype(_dtype(cfg.compute_dtype))
    x, caches = decode_stack(
        params["decoder"], layer_segments(cfg), cfg, x, caches, pos,
        shared_params=params.get("shared_attn"),
    )
    return _logits(params, cfg, x), caches
