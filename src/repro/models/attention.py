"""Attention variants: GQA (with optional sliding window), MLA
(DeepSeek-V3 multi-head latent attention, compressed KV cache), and
cross-attention (enc-dec). Full-sequence and single-token-decode paths.

All shapes: x (b, s, d); caches are (b, S_max, ...). The decode-path
``pos`` write index is either a () scalar (batch decodes in lockstep)
or a (b,) vector (continuous batching: each row decodes at its own
position — the serving layer admits new requests into freed slots, so
rows are at different depths). A scalar is broadcast to (b,), and the
per-row scatter write places exactly the same elements as the old
lockstep dynamic-slice write, so scalar-pos decode is bit-identical to
the pre-vectorized path.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import ArchConfig
from repro.models.layers import apply_rope, init_linear, rope_freqs

NEG_INF = -1e30


def _causal_window_mask(
    qpos: jnp.ndarray, kpos: jnp.ndarray, window: int | None
) -> jnp.ndarray:
    """(.., sq, sk) boolean mask: kpos <= qpos (& within window)."""
    m = kpos[..., None, :] <= qpos[..., :, None]
    if window is not None:
        m &= kpos[..., None, :] > qpos[..., :, None] - window
    return m


def _sdpa(q, k, v, mask, scale):
    """q (b,sq,K,G,h), k/v (b,sk,K,h), mask (b,sq,sk) -> (b,sq,K,G,h)."""
    scores = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    scores = jnp.where(mask[:, None, None, :, :], scores.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", probs, v)


# ----------------------------------------------------------------------------
# GQA
# ----------------------------------------------------------------------------


def init_gqa(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    hd = cfg.hd()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": init_linear(k2, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": init_linear(k3, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": init_linear(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def _gqa_qkv(params, x, positions, cfg: ArchConfig):
    b, s, _ = x.shape
    hd = cfg.hd()
    H, K = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype)).reshape(b, s, H, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"].astype(x.dtype)).reshape(b, s, K, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"].astype(x.dtype)).reshape(b, s, K, hd)
    cos, sin = rope_freqs(positions, hd, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    return q, k, v


def gqa_full(
    params: dict,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cfg: ArchConfig,
    window: int | None = None,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence causal attention. Returns (out, (k, v)) — k/v are
    returned so prefill can seed the cache."""
    b, s, _ = x.shape
    hd = cfg.hd()
    H, K = cfg.num_heads, cfg.num_kv_heads
    G = H // K
    q, k, v = _gqa_qkv(params, x, positions, cfg)
    qg = q.reshape(b, s, K, G, hd)
    mask = _causal_window_mask(positions, positions, window)
    out = _sdpa(qg, k, v, mask, hd ** -0.5).reshape(b, s, H * hd)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return out, (k, v)


def gqa_decode(
    params: dict,
    x: jnp.ndarray,  # (b, 1, d)
    cache_k: jnp.ndarray,  # (b, S, K, hd)
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,  # () or (b,) int32 — current write position(s)
    cfg: ArchConfig,
    window: int | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """One-token decode against a KV cache. Returns (out, k', v')."""
    b = x.shape[0]
    hd = cfg.hd()
    H, K = cfg.num_heads, cfg.num_kv_heads
    G = H // K
    pos_b = jnp.broadcast_to(pos, (b,)).astype(jnp.int32)
    positions = pos_b[:, None]
    q, k_new, v_new = _gqa_qkv(params, x, positions, cfg)
    S = cache_k.shape[1]
    # ring-buffer mode: a windowed layer whose cache is sized below the
    # decode horizon writes at pos % S; keys carry their absolute-pos
    # RoPE phases so the ring is transparent to attention.
    write_pos = pos_b % S
    rows = jnp.arange(b)
    cache_k = cache_k.at[rows, write_pos].set(k_new[:, 0].astype(cache_k.dtype))
    cache_v = cache_v.at[rows, write_pos].set(v_new[:, 0].astype(cache_v.dtype))
    slot = jnp.arange(S, dtype=jnp.int32)
    # absolute position currently held by each ring slot, per row
    kpos = pos_b[:, None] - (pos_b[:, None] - slot[None, :]) % S
    mask = _causal_window_mask(positions, kpos, window) & (kpos[:, None, :] >= 0)
    qg = q.reshape(b, 1, K, G, hd)
    out = _sdpa(qg, cache_k.astype(x.dtype), cache_v.astype(x.dtype), mask, hd ** -0.5)
    out = out.reshape(b, 1, H * hd)
    out = jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
    return out, cache_k, cache_v


# ----------------------------------------------------------------------------
# MLA (DeepSeek-V3): low-rank Q, compressed latent KV cache, rope/nope split
# ----------------------------------------------------------------------------


def init_mla(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    ks = jax.random.split(key, 8)
    H = cfg.num_heads
    qk_dim = cfg.qk_nope_head_dim + cfg.qk_rope_head_dim
    p = {
        "wkv_a": init_linear(ks[2], cfg.d_model, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtype),
        "kv_norm": jnp.zeros((cfg.kv_lora_rank,), dtype),
        # absorbed projections, stored per-head
        "wkv_b_k": (jax.random.normal(ks[3], (H, cfg.qk_nope_head_dim, cfg.kv_lora_rank))
                    * cfg.kv_lora_rank ** -0.5).astype(dtype),
        "wkv_b_v": (jax.random.normal(ks[4], (H, cfg.kv_lora_rank, cfg.v_head_dim))
                    * cfg.kv_lora_rank ** -0.5).astype(dtype),
        "wo": init_linear(ks[5], H * cfg.v_head_dim, cfg.d_model, dtype),
    }
    if cfg.q_lora_rank:
        p["wq_a"] = init_linear(ks[0], cfg.d_model, cfg.q_lora_rank, dtype)
        p["q_norm"] = jnp.zeros((cfg.q_lora_rank,), dtype)
        p["wq_b"] = init_linear(ks[1], cfg.q_lora_rank, H * qk_dim, dtype)
    else:
        p["wq"] = init_linear(ks[0], cfg.d_model, H * qk_dim, dtype)
    return p


def _mla_q(params, x, positions, cfg: ArchConfig):
    from repro.models.layers import rms_norm

    b, s, _ = x.shape
    H = cfg.num_heads
    nd, rd = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim
    if cfg.q_lora_rank:
        ql = jnp.einsum("bsd,dr->bsr", x, params["wq_a"].astype(x.dtype))
        ql = rms_norm(ql, params["q_norm"], cfg.norm_eps)
        q = jnp.einsum("bsr,re->bse", ql, params["wq_b"].astype(x.dtype))
    else:
        q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype))
    q = q.reshape(b, s, H, nd + rd)
    q_nope, q_rope = q[..., :nd], q[..., nd:]
    cos, sin = rope_freqs(positions, rd, cfg.rope_theta)
    q_rope = apply_rope(q_rope, cos, sin)
    # absorb the key up-projection into the query -> latent space
    q_lat = jnp.einsum("bshn,hnr->bshr", q_nope, params["wkv_b_k"].astype(x.dtype))
    return q_lat, q_rope


def _mla_kv_latent(params, x, positions, cfg: ArchConfig):
    from repro.models.layers import rms_norm

    rd = cfg.qk_rope_head_dim
    kv = jnp.einsum("bsd,dr->bsr", x, params["wkv_a"].astype(x.dtype))
    c_kv, k_rope = kv[..., : cfg.kv_lora_rank], kv[..., cfg.kv_lora_rank:]
    c_kv = rms_norm(c_kv, params["kv_norm"], cfg.norm_eps)
    cos, sin = rope_freqs(positions, rd, cfg.rope_theta)
    k_rope = apply_rope(k_rope[..., None, :], cos, sin)[..., 0, :]  # shared head
    return c_kv, k_rope


def _mla_attend(params, q_lat, q_rope, c_kv, k_rope, mask, cfg: ArchConfig, dtype):
    scale = (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim) ** -0.5
    scores = jnp.einsum("bqhr,bsr->bhqs", q_lat, c_kv) + jnp.einsum(
        "bqhr,bsr->bhqs", q_rope, k_rope
    )
    scores = jnp.where(mask[:, None, :, :], scores.astype(jnp.float32) * scale, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(dtype)
    out_lat = jnp.einsum("bhqs,bsr->bqhr", probs, c_kv)
    v = jnp.einsum("bqhr,hrv->bqhv", out_lat, params["wkv_b_v"].astype(dtype))
    b, s = v.shape[0], v.shape[1]
    out = v.reshape(b, s, cfg.num_heads * cfg.v_head_dim)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(dtype))


def mla_full(
    params: dict, x: jnp.ndarray, positions: jnp.ndarray, cfg: ArchConfig
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    q_lat, q_rope = _mla_q(params, x, positions, cfg)
    c_kv, k_rope = _mla_kv_latent(params, x, positions, cfg)
    mask = _causal_window_mask(positions, positions, None)
    out = _mla_attend(params, q_lat, q_rope, c_kv, k_rope, mask, cfg, x.dtype)
    return out, (c_kv, k_rope)


def mla_decode(
    params: dict,
    x: jnp.ndarray,
    cache_ckv: jnp.ndarray,  # (b, S, kv_lora_rank)
    cache_krope: jnp.ndarray,  # (b, S, qk_rope_head_dim)
    pos: jnp.ndarray,  # () or (b,) int32
    cfg: ArchConfig,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    b = x.shape[0]
    pos_b = jnp.broadcast_to(pos, (b,)).astype(jnp.int32)
    positions = pos_b[:, None]
    q_lat, q_rope = _mla_q(params, x, positions, cfg)
    c_new, r_new = _mla_kv_latent(params, x, positions, cfg)
    rows = jnp.arange(b)
    cache_ckv = cache_ckv.at[rows, pos_b].set(c_new[:, 0].astype(cache_ckv.dtype))
    cache_krope = cache_krope.at[rows, pos_b].set(r_new[:, 0].astype(cache_krope.dtype))
    S = cache_ckv.shape[1]
    kpos = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (b, S))
    mask = _causal_window_mask(positions, kpos, None)
    out = _mla_attend(
        params, q_lat, q_rope, cache_ckv.astype(x.dtype), cache_krope.astype(x.dtype), mask, cfg, x.dtype
    )
    return out, cache_ckv, cache_krope


# ----------------------------------------------------------------------------
# Cross-attention (enc-dec decoder layers)
# ----------------------------------------------------------------------------


def init_cross(key: jax.Array, cfg: ArchConfig, dtype) -> dict:
    hd = cfg.hd()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": init_linear(k1, cfg.d_model, cfg.num_heads * hd, dtype),
        "wk": init_linear(k2, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wv": init_linear(k3, cfg.d_model, cfg.num_kv_heads * hd, dtype),
        "wo": init_linear(k4, cfg.num_heads * hd, cfg.d_model, dtype),
    }


def cross_kv(params: dict, enc: jnp.ndarray, cfg: ArchConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Precompute encoder-side K/V once per request (prefill)."""
    b, s, _ = enc.shape
    hd = cfg.hd()
    K = cfg.num_kv_heads
    k = jnp.einsum("bsd,de->bse", enc, params["wk"].astype(enc.dtype)).reshape(b, s, K, hd)
    v = jnp.einsum("bsd,de->bse", enc, params["wv"].astype(enc.dtype)).reshape(b, s, K, hd)
    return k, v


def cross_attend(
    params: dict, x: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, cfg: ArchConfig
) -> jnp.ndarray:
    b, s, _ = x.shape
    hd = cfg.hd()
    H, K = cfg.num_heads, cfg.num_kv_heads
    G = H // K
    q = jnp.einsum("bsd,de->bse", x, params["wq"].astype(x.dtype)).reshape(b, s, K, G, hd)
    mask = jnp.ones((b, s, k.shape[1]), bool)  # full visibility of encoder
    out = _sdpa(q, k.astype(x.dtype), v.astype(x.dtype), mask, hd ** -0.5).reshape(b, s, H * hd)
    return jnp.einsum("bse,ed->bsd", out, params["wo"].astype(x.dtype))
