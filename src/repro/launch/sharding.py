"""Sharding policy: parameter, batch, and cache PartitionSpecs.

Scheme (DESIGN.md §5): tensor parallelism over ``model`` (attention
heads / FFN hidden / experts), FSDP-style parameter sharding over
``data``; the ``pod`` axis is pure data parallelism (params replicated
across pods; DCN-friendly). MoE expert weights shard the expert dim
over ``model`` (expert parallelism) and the d_model dim over ``data``.

Rules are name+rank based and tolerate the extra leading stack axis the
segment scan adds (an extra leading ``None``).
"""

from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.models.config import ArchConfig

FSDP = "data"
TP = "model"


def _base_rule(names: list[str], cfg: ArchConfig) -> tuple:
    """PartitionSpec elements for the UNSTACKED leaf."""
    leaf = names[-1]
    in_moe = "moe" in names
    in_ssm = "ssm" in names

    if "shared_attn" in names:
        # zamba2's weight-shared block is applied 7x per pass; FSDP on it
        # costs an all-gather per application (§Perf hillclimb B) while
        # the whole block is ~184MB bf16 — keep it TP-only, no FSDP.
        if leaf in ("wq", "wk", "wv", "gate", "up"):
            return (None, TP)
        if leaf in ("wo", "down"):
            return (TP, None)
        return None

    if leaf == "embed":
        return (TP, FSDP)
    if leaf == "lm_head":
        # no FSDP on the head: sharding its contraction dim over `data`
        # makes XLA all-reduce (b,s,V) activations — measured 2x13GB per
        # step on mamba2 (EXPERIMENTS.md §Perf). TP on vocab only.
        return (None, TP)
    if leaf in ("frontend_proj", "mtp_head"):
        return (None, TP)
    if leaf == "router":
        return (None, None)
    if in_moe and leaf in ("gate", "up"):
        # expert parallelism when E divides the 16-way TP axis; else
        # shard the expert FFN dim instead (grok-1 has E=8: dropping the
        # axis silently left 38.8GB/dev of expert weights resident)
        if cfg.num_experts % 16 == 0:
            return (TP, FSDP, None)  # (E, d, f)
        return (None, FSDP, TP)
    if in_moe and leaf == "down":
        if cfg.num_experts % 16 == 0:
            return (TP, None, FSDP)  # (E, f, d)
        return (None, TP, FSDP)
    if leaf in ("gate", "up"):
        return (FSDP, TP)
    if leaf == "down":
        return (TP, FSDP)
    if leaf in ("wq", "wk", "wv", "wq_b"):
        return (FSDP, TP) if leaf != "wq_b" else (None, TP)
    if leaf == "wo":
        return (TP, FSDP)
    if leaf in ("wq_a", "wkv_a"):
        return (FSDP, None)
    if leaf in ("wkv_b_k", "wkv_b_v"):
        return (TP, None, None)
    if in_ssm and leaf == "in_proj":
        return (FSDP, TP)
    if in_ssm and leaf == "out_proj":
        return (TP, FSDP)
    if in_ssm and leaf in ("conv_w",):
        return (None, TP)
    if in_ssm and leaf in ("conv_b", "norm"):
        return (TP,)
    # norms, biases, scalars-per-head (a_log, dt_bias, D), kv_norm, q_norm
    return None  # replicate


def fit_spec(spec: P, shape: tuple[int, ...], axis_sizes: dict[str, int]) -> P:
    """Drop sharding axes that don't evenly divide the dimension (jit
    input shardings require exact divisibility). E.g. vocab=50280 can't
    shard 16-way -> replicated; kv_heads=4 over a 16-way axis -> local."""
    parts = list(spec) + [None] * (len(shape) - len(spec))
    out = []
    for dim, part in zip(shape, parts):
        if part is None:
            out.append(None)
            continue
        axes = part if isinstance(part, tuple) else (part,)
        size = 1
        for a in axes:
            size *= axis_sizes[a]
        out.append(part if dim % size == 0 else None)
    return P(*out)


def fit_sharding_tree(mesh, spec_tree, shape_tree):
    """NamedSharding tree with per-leaf divisibility fixes."""
    from jax.sharding import NamedSharding

    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return jax.tree.map(
        lambda spec, s: NamedSharding(mesh, fit_spec(spec, s.shape, axis_sizes)),
        spec_tree,
        shape_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _names(path) -> list[str]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
    return out


def _serve_rule(rule: tuple | None, names: list[str]) -> tuple | None:
    """Serving keeps weights resident: no FSDP over ``data`` for 2D
    weights (a per-token all-gather would dominate decode — measured in
    EXPERIMENTS.md §Perf). 3D expert weights stay 2D-sharded
    (E replicated-or-model, d over data, f over model) so giants still
    fit; the resulting all-reduce is tiny (capacity x f)."""
    if rule is None:
        return None
    if len(rule) == 3 and "moe" in names:
        return (None, FSDP, TP)
    return tuple(None if r == FSDP else r for r in rule)


def param_pspecs(params_shapes, cfg: ArchConfig, mode: str = "train"):
    """PartitionSpec pytree matching a params (shape) pytree.

    mode: "train" (FSDP+TP) or "serve" (TP-resident; see _serve_rule).
    """

    def spec_for(path, leaf):
        names = _names(path)
        rule = _base_rule(names, cfg)
        if mode == "serve":
            rule = _serve_rule(rule, names)
        if rule is None:
            return P()
        rank = len(leaf.shape)
        pad = rank - len(rule)
        if pad < 0:  # e.g. reduced configs; replicate rather than crash
            return P()
        return P(*((None,) * pad + tuple(rule)))

    return jax.tree_util.tree_map_with_path(spec_for, params_shapes)


def opt_pspecs(params_pspecs):
    """Optimizer state mirrors the params sharding; step is replicated."""
    return {
        "mu": params_pspecs,
        "nu": params_pspecs,
        "step": P(),
    }


def batch_pspecs(batch_shapes, dp: tuple[str, ...], shard_batch: bool = True):
    lead = dp if shard_batch else None

    def spec_for(path, leaf):
        rank = len(leaf.shape)
        return P(*((lead,) + (None,) * (rank - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shapes)


def cache_pspecs(cache_shapes, cfg: ArchConfig, dp: tuple[str, ...], long_context: bool):
    """Decode-cache specs.

    Normal decode: batch over the data axes, everything else local
    (heads often don't divide the 16-way model axis; XLA would pad).
    Long-context (batch=1): shard the cache *sequence* dim over
    ``model`` instead (flash-decoding style split; softmax combines
    partial sums with the collectives XLA inserts). SSM states shard
    heads over ``model``.
    """

    def spec_for(path, leaf):
        shape = leaf.shape
        rank = len(shape)
        if rank == 5:  # (reps, b, S, K, hd) kv OR (reps, b, H, N, P) ssm state
            # distinguish: kv caches have shape[2] == max_len (large)
            is_kv = shape[2] >= 4096
            if is_kv:
                # sequence over `model` (flash-decoding split: kv heads
                # rarely divide a 16-way axis; the cache must not be
                # replicated across it — measured 64GB/step all-gathers
                # otherwise), batch over the data axes.
                return P(None, None if long_context else dp, TP, None, None)
            return P(None, dp if not long_context else None, TP, None, None)
        if rank == 4:  # (reps, b, S, r) mla latent or (reps, b, k-1, ch) conv
            is_kv = shape[2] >= 4096
            if is_kv:
                return P(None, None if long_context else dp, TP, None)
            return P(None, dp if not long_context else None, None, TP)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache_shapes)
