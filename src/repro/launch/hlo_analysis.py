"""Exact collective accounting from optimized per-partition HLO.

XLA's ``cost_analysis()`` counts a while-loop (scan) body ONCE, not by
trip count, so any layer-scanned program under-reports by ~L x. The
optimized HLO, however, annotates every while op with
``known_trip_count`` — so we parse the module into computations, build
the while/call nesting graph, and multiply each computation's
collective bytes by the product of its enclosing trip counts. This
gives exact per-device collective traffic for §Roofline.
"""

from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}
COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")


def roofline(flops: float, bytes_accessed: float, *, peak_flops: float, hbm_bw: float) -> dict:
    """Classic two-term roofline: arithmetic intensity vs the machine's
    ridge point, plus the projected per-invocation floor (the larger of
    the memory and compute terms)."""
    intensity = flops / max(bytes_accessed, 1.0)
    ridge = peak_flops / hbm_bw
    return {
        "flops": float(flops),
        "bytes_accessed": float(bytes_accessed),
        "arith_intensity_flops_per_byte": intensity,
        "ridge_point_flops_per_byte": ridge,
        "bound": "memory" if intensity < ridge else "compute",
        "projected_us": 1e6 * max(bytes_accessed / hbm_bw, flops / peak_flops),
    }


def round_step_roofline(w: int, capacity: int, *, eps: float = 0.0) -> dict:
    """Roofline accounting of the fused round-step kernel at ``(W, C)``.

    ``cost_analysis()`` cannot see inside a Pallas custom-call, so this
    lowers the bit-identical jnp reference (``kernels/ref.round_step_ref``
    — same math, same operand set) and reads the optimized-HLO flops and
    bytes accessed, then classifies them against the launch/mesh.py
    per-chip constants. ``operand_bytes`` is the approximate floor the
    fused kernel must move (four ``(W, C)`` queue leaves in, the cert
    plane out, plus the per-worker vectors); ``fusion_overhead_x`` =
    hlo_bytes / operand_bytes shows how far XLA's fusion of the
    multi-pass reference sits above that floor — the gap the single-pass
    Pallas kernel closes.
    """
    import functools

    import jax
    import jax.numpy as jnp

    from repro.kernels.ref import round_step_ref
    from repro.launch.mesh import HBM_BW, PEAK_FLOPS_BF16

    qf = jax.ShapeDtypeStruct((w, capacity), jnp.float32)
    qi = jax.ShapeDtypeStruct((w, capacity), jnp.int32)
    vf = jax.ShapeDtypeStruct((w,), jnp.float32)
    vb = jax.ShapeDtypeStruct((w,), jnp.bool_)
    r = jax.ShapeDtypeStruct((), jnp.int32)
    fn = functools.partial(round_step_ref, eps=eps)
    compiled = jax.jit(fn).lower(qf, qi, qi, qi, vf, vb, vf, vf, r).compile()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    hlo_bytes = float(ca.get("bytes accessed", 0.0))
    operand_bytes = float((5 * capacity + 11) * w * 4)
    out = roofline(flops, hlo_bytes, peak_flops=PEAK_FLOPS_BF16, hbm_bw=HBM_BW)
    out["w"], out["capacity"] = w, capacity
    out["operand_bytes"] = operand_bytes
    out["fusion_overhead_x"] = hlo_bytes / max(operand_bytes, 1.0)
    return out

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]"
)
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-_]+)\s*\([^)]*\)\s*->")
_WHILE_RE = re.compile(r"while\(.*?\).*?body=%?([\w.\-_]+)")
_TRIP_RE = re.compile(r'known_trip_count[^0-9]*?(\d+)')
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-_]+)")
_COND_RE = re.compile(r"conditional\(.*")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


def parse_collectives(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind bytes, weighted by loop trip counts."""
    # 1. split into computations
    comps: dict[str, list[str]] = {}
    entry = None
    cur = None
    for line in hlo_text.splitlines():
        if not line.startswith(" "):
            m = _COMP_HDR.match(line.strip())
            if m and "{" in line:
                cur = m.group(1)
                comps[cur] = []
                if line.strip().startswith("ENTRY"):
                    entry = cur
            continue
        if cur is not None:
            comps[cur].append(line.strip())

    # 2. per-computation direct collective bytes + child edges
    direct: dict[str, dict[str, int]] = {c: defaultdict(int) for c in comps}
    children: dict[str, list[tuple[str, int]]] = {c: [] for c in comps}
    for c, lines in comps.items():
        for s in lines:
            if " = " not in s:
                continue
            rhs = s.split(" = ", 1)[1]
            head = rhs.split("(", 1)[0].strip()
            opname = head.split()[-1] if head else ""
            base = opname[:-6] if opname.endswith("-start") else opname
            if base in COLLECTIVES:
                direct[c][base] += _shape_bytes(rhs.split("(", 1)[0])
            wm = _WHILE_RE.search(s)
            if wm:
                body = wm.group(1)
                tm = _TRIP_RE.search(s)
                trip = int(tm.group(1)) if tm else 1
                children[c].append((body, trip))
                continue
            cm = _CALL_RE.search(s)
            if cm and cm.group(1) in comps:
                children[c].append((cm.group(1), 1))

    # 3. accumulate multipliers from entry
    mult: dict[str, float] = defaultdict(float)
    if entry is None:
        entry = next(iter(comps), None)
    if entry is None:
        return {k: 0.0 for k in COLLECTIVES}
    stack = [(entry, 1.0)]
    seen_edges = 0
    while stack:
        comp, m = stack.pop()
        mult[comp] += m
        for child, trip in children.get(comp, ()):
            seen_edges += 1
            if seen_edges > 100_000:  # cycle guard
                break
            stack.append((child, m * trip))

    out = {k: 0.0 for k in COLLECTIVES}
    for c, d in direct.items():
        if mult.get(c, 0.0) <= 0.0:
            # unreachable from entry (e.g. while condition) — count once
            continue
        for k, v in d.items():
            out[k] += v * mult[c]
    return out
