"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before the first jax call.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the same step functions."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_worker_mesh(num_devices: int | None = None):
    """1-D ``workers`` mesh for the device-sharded TMSN engine.

    ``num_devices=None`` takes every visible device (on CI that is the
    8 forced host devices from ``--xla_force_host_platform_device_count``;
    on a TPU pod slice, the real chips). The engine shards the stacked
    ``(W, ...)`` worker state over this axis, so ``n_workers`` must be
    a multiple of the mesh size.
    """
    if num_devices is None:
        num_devices = len(jax.devices())
    if num_devices < 1 or num_devices > len(jax.devices()):
        raise ValueError(
            f"num_devices={num_devices} not in [1, {len(jax.devices())}] visible devices"
        )
    return jax.make_mesh((num_devices,), ("workers",))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link


def ici_round_seconds(gossip_bytes_per_round: int, bandwidth: float = ICI_BW) -> float:
    """Lower-bound wire seconds one gossip round would spend on a single
    ICI link, from the engine's logical ``gossip_bytes_per_round``.

    A derived estimate for benchmark reporting (dense vs gated gossip),
    not a measurement — the ROADMAP's real-interconnect item is about
    replacing this with profiler traces on hardware."""
    return float(gossip_bytes_per_round) / float(bandwidth)
