"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before the first jax call.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the same step functions."""
    return jax.make_mesh((1, 1), ("data", "model"))


def make_worker_mesh(num_devices: int | None = None, pods: int = 1):
    """Worker mesh for the device-sharded TMSN engine.

    ``pods=1`` (default) builds the 1-D ``("workers",)`` mesh: one
    interconnect tier, gossip is a single all_gather over every device.
    ``pods > 1`` builds the hierarchical 2-D ``("pod", "workers")``
    mesh — ``pods`` groups of ``num_devices / pods`` devices each, with
    ``pod`` as the slow (device-order-major) axis so the flat device
    order matches the 1-D mesh. The engine then keeps per-round gossip
    on the ``workers`` (ICI) axis and exchanges only the freshest
    pending certificates over the ``pod`` (DCN) axis every
    ``EngineConfig.cross_pod_every_k`` rounds.

    ``num_devices=None`` takes every visible device (on CI that is the
    8 forced host devices from ``--xla_force_host_platform_device_count``;
    on a TPU pod slice, the real chips). The engine shards the stacked
    ``(W, ...)`` worker state over the whole mesh, so ``n_workers`` must
    be a multiple of the total device count.
    """
    if num_devices is None:
        num_devices = len(jax.devices())
    if num_devices < 1 or num_devices > len(jax.devices()):
        raise ValueError(
            f"num_devices={num_devices} not in [1, {len(jax.devices())}] visible devices"
        )
    if pods < 1:
        raise ValueError(f"pods={pods} must be >= 1")
    if pods == 1:
        return jax.make_mesh((num_devices,), ("workers",))
    if num_devices % pods:
        raise ValueError(f"num_devices={num_devices} must divide into {pods} pods")
    return jax.make_mesh((pods, num_devices // pods), ("pod", "workers"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
# data-center network between pods — order 100 Gbit/s per host, an
# order of magnitude under ICI; the gap is why the pod-mesh engine
# moves cross-pod payloads only every cross_pod_every_k rounds
DCN_BW = 12.5e9  # B/s


def ici_round_seconds(
    gossip_bytes_per_round: int,
    bandwidth: float = ICI_BW,
    control_bytes_per_round: int = 0,
) -> float:
    """Lower-bound wire seconds one gossip round would spend on a single
    ICI link, from the engine's logical ``gossip_bytes_per_round``.

    ``control_bytes_per_round`` adds the control-plane exchange
    (certificates/flags/ids) when the caller reports the two planes
    separately — pass 0 (default) when the gossip figure already
    includes it, as ``SimResult.gossip_bytes_per_round`` does.

    A derived estimate for benchmark reporting (dense vs gated gossip,
    dense vs sparse control), not a measurement — the ROADMAP's
    real-interconnect item is about replacing this with profiler traces
    on hardware."""
    return float(gossip_bytes_per_round + control_bytes_per_round) / float(bandwidth)


def dcn_round_seconds(
    dcn_bytes_per_round: int,
    bandwidth: float = DCN_BW,
    control_bytes_per_round: int = 0,
) -> float:
    """Lower-bound wire seconds per round on the cross-pod DCN tier,
    from the pod-mesh engine's amortized ``gossip_bytes_per_round_dcn``
    (plus, optionally, a separately-reported control-plane share).
    Same derived-not-measured formula as the ICI tier, at DCN bandwidth."""
    return ici_round_seconds(
        dcn_bytes_per_round, bandwidth, control_bytes_per_round=control_bytes_per_round
    )
