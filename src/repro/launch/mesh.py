"""Production meshes.

Single pod: (data=16, model=16) = 256 chips (TPU v5e pod).
Multi-pod: (pod=2, data=16, model=16) = 512 chips.

Defined as functions (never module-level constants) so importing this
module never touches jax device state — the dry-run must set
``xla_force_host_platform_device_count`` before the first jax call.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """1-device mesh for CPU smoke runs of the same step functions."""
    return jax.make_mesh((1, 1), ("data", "model"))


def data_axes(mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


# Hardware constants for the roofline (TPU v5e per chip)
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW = 50e9  # B/s per link
