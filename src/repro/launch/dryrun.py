import os

os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 " + os.environ.get("XLA_FLAGS", "")
)

"""Multi-pod dry-run driver (deliverable e).

For every (architecture x input shape x mesh) combination:
``jax.jit(step).lower(**input_specs).compile()`` against the production
mesh, then extract

  * ``compiled.memory_analysis()``  — bytes per device (fits-or-not),
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline,
  * collective bytes parsed from the optimized per-partition HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute output sizes),

and emit a JSON record consumed by benchmarks/roofline.py.

Usage:
  python -m repro.launch.dryrun --arch yi-9b --shape train_4k
  python -m repro.launch.dryrun --arch all --shape all [--multipod] [--tmsn]
"""

import argparse
import dataclasses
import json
import re
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.launch.mesh import (
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS_BF16,
    data_axes,
    make_production_mesh,
)
from repro.launch.sharding import (
    batch_pspecs,
    cache_pspecs,
    fit_sharding_tree,
    fit_spec,
    opt_pspecs,
    param_pspecs,
)
from repro.launch.steps import (
    INPUT_SHAPES,
    batch_specs,
    decode_specs,
    dryrun_cfg,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    opt_config_for,
    shape_applicable,
)
from repro.models import init_params
from repro.models.config import ArchConfig
from repro.optim import init_opt_state

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results", "dryrun")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all", "collective-permute")
_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]"
)


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output-shape bytes of every collective op in the optimized
    per-partition HLO. ``-start`` variants counted, ``-done`` skipped
    (they share the same buffer)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if " = " not in s:
            continue
        lhs, rhs = s.split(" = ", 1)
        for coll in _COLLECTIVES:
            # match `opcode(` or `opcode-start(` at the beginning of rhs
            head = rhs.split("(", 1)[0].strip()
            # strip the shape prefix from rhs head: "bf16[...] all-reduce"
            opname = head.split()[-1] if head else ""
            if opname in (coll, coll + "-start"):
                out[coll] += _shape_bytes(rhs.split("(", 1)[0])
                break
    return out


def active_param_fraction(cfg: ArchConfig) -> float:
    """Fraction of parameters active per token (MoE top-k)."""
    if not cfg.num_experts:
        return 1.0
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    tot = sum(int(np.prod(x.shape)) for _, x in flat)
    expert = sum(
        int(np.prod(x.shape))
        for kp, x in flat
        if any(getattr(p, "key", None) == "moe" for p in kp)
        and str(kp[-1].key) in ("gate", "up", "down")
    )
    frac_active = cfg.num_experts_per_tok / cfg.num_experts
    return (tot - expert + expert * frac_active) / tot


def build_case(cfg: ArchConfig, shape_name: str, mesh):
    """Returns (jitted_fn, arg_shapes) ready for .lower()."""
    seq, gb, kind = INPUT_SHAPES[shape_name]
    dp = data_axes(mesh)
    params_shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    mode = "train" if kind == "train" else "serve"
    p_specs = param_pspecs(params_shapes, cfg, mode=mode)
    p_sh = fit_sharding_tree(mesh, p_specs, params_shapes)
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))

    if kind == "train":
        opt_cfg = opt_config_for(cfg)
        opt_shapes = jax.eval_shape(lambda: init_opt_state(params_shapes, opt_cfg))
        o_specs = opt_pspecs(p_specs)
        o_sh = fit_sharding_tree(mesh, o_specs, opt_shapes)
        b_shapes = batch_specs(cfg, shape_name)
        b_sh = fit_sharding_tree(mesh, batch_pspecs(b_shapes, dp), b_shapes)
        fn = jax.jit(
            make_train_step(cfg, opt_cfg),
            in_shardings=(p_sh, o_sh, b_sh),
            out_shardings=(p_sh, o_sh, None),
            donate_argnums=(0, 1),
        )
        return fn, (params_shapes, opt_shapes, b_shapes)

    if kind == "prefill":
        b_shapes = batch_specs(cfg, shape_name)
        b_sh = fit_sharding_tree(mesh, batch_pspecs(b_shapes, dp), b_shapes)
        fn = jax.jit(
            make_prefill_step(cfg),
            in_shardings=(p_sh, b_sh),
        )
        return fn, (params_shapes, b_shapes)

    # decode
    d = decode_specs(cfg, shape_name)
    long_ctx = gb == 1
    c_sh = fit_sharding_tree(
        mesh, cache_pspecs(d["caches"], cfg, dp, long_context=long_ctx), d["caches"]
    )
    tok_spec = fit_spec(P(dp, None) if not long_ctx else P(None, None), (gb, 1), axis_sizes)
    tok_sh = NamedSharding(mesh, tok_spec)
    fn = jax.jit(
        make_serve_step(cfg),
        in_shardings=(p_sh, tok_sh, c_sh, NamedSharding(mesh, P())),
        out_shardings=(tok_sh, c_sh),
        donate_argnums=(2,),
    )
    return fn, (params_shapes, d["token"], d["caches"], d["pos"])


def build_tmsn_case(cfg: ArchConfig, shape_name: str, mesh):
    """Lower one TMSN-SGD round (beyond-paper training strategy)."""
    from repro.core.tmsn_sgd import TMSNSGDConfig, make_tmsn_round, tmsn_batch_specs

    seq, gb, kind = INPUT_SHAPES[shape_name]
    assert kind == "train"
    multi = "pod" in mesh.axis_names
    w_axis = "pod" if multi else "data"
    W = dict(zip(mesh.axis_names, mesh.devices.shape))[w_axis]
    tcfg = TMSNSGDConfig(num_workers=W, local_steps=4, unroll=cfg.scan_unroll)
    opt_cfg = opt_config_for(cfg)

    params_shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    base = param_pspecs(params_shapes, cfg)

    def lift(spec: P) -> P:
        parts = tuple(spec)
        if not multi:
            # single pod: the worker axis consumes "data" (no FSDP within
            # a group; params sharded over "model" only)
            parts = tuple(None if p == "data" else p for p in parts)
        return P(w_axis, *parts)

    pw_specs = jax.tree.map(lift, base, is_leaf=lambda x: isinstance(x, P))
    ow_specs = {"mu": pw_specs, "nu": pw_specs, "step": P(w_axis)}
    b_shapes = tmsn_batch_specs(cfg, tcfg, seq, gb)
    b_specs = jax.tree.map(lambda s: P(w_axis, *((None,) * (len(s.shape) - 1))), b_shapes)

    pw_shapes = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((W,) + s.shape, s.dtype), params_shapes
    )
    opt_cfg_dt = jnp.bfloat16 if opt_cfg.state_dtype == "bfloat16" else jnp.float32
    ow_shapes = {
        "mu": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, opt_cfg_dt), pw_shapes),
        "nu": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, opt_cfg_dt), pw_shapes),
        "step": jax.ShapeDtypeStruct((W,), jnp.int32),
    }
    cert_shape = jax.ShapeDtypeStruct((W,), jnp.float32)

    pw_sh = fit_sharding_tree(mesh, pw_specs, pw_shapes)
    ow_sh = fit_sharding_tree(mesh, ow_specs, ow_shapes)
    b_sh = fit_sharding_tree(mesh, b_specs, b_shapes)
    fn = jax.jit(
        make_tmsn_round(cfg, opt_cfg, tcfg),
        in_shardings=(pw_sh, ow_sh, NamedSharding(mesh, P(w_axis)), b_sh),
        out_shardings=(pw_sh, ow_sh, NamedSharding(mesh, P(w_axis)), None),
        donate_argnums=(0, 1),
    )
    return fn, (pw_shapes, ow_shapes, cert_shape, b_shapes), tcfg


OPT_KNOBS_DOC = '''--opt applies the §Perf optimized configuration:
  * act_dp: with_sharding_constraint on the layer-scan carry (keeps the
    batch dim sharded inside while bodies),
  * vocab_pad_multiple=256: pad embed/lm_head so the vocab dim shards
    over the 16-way model axis (exact-CE masking on padded columns),
  * ssm_chunk=64 (SSM archs): 4x smaller SSD decay-mask temporaries.'''


def optimize_cfg(cfg: ArchConfig, mesh) -> ArchConfig:
    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    kw = dict(act_dp=dp, vocab_pad_multiple=256, windowed_cache=True)
    if cfg.ssm_state:
        kw["ssm_chunk"] = 64
    return dataclasses.replace(cfg, **kw)


def run_one(arch: str, shape_name: str, multi_pod: bool, tmsn: bool = False, opt: bool = False) -> dict:
    cfg0 = get_config(arch)
    cfg = dryrun_cfg(cfg0)
    mesh = make_production_mesh(multi_pod=multi_pod)
    if opt:
        cfg = optimize_cfg(cfg, mesh)
    n_chips = int(np.prod(mesh.devices.shape))
    rec: dict = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "chips": n_chips,
        "tmsn": tmsn,
        "opt": opt,
    }
    ok, why = shape_applicable(cfg, shape_name)
    if not ok:
        rec.update(status="skip", reason=why)
        return rec
    try:
        t0 = time.time()
        if tmsn:
            fn, arg_shapes, _ = build_tmsn_case(cfg, shape_name, mesh)
        else:
            fn, arg_shapes = build_case(cfg, shape_name, mesh)
        with mesh:
            lowered = fn.lower(*arg_shapes)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0
        rec["lower_s"] = round(t_lower, 1)
        rec["compile_s"] = round(t_compile, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(mem, k))
            for k in (
                "argument_size_in_bytes",
                "output_size_in_bytes",
                "temp_size_in_bytes",
                "generated_code_size_in_bytes",
            )
            if hasattr(mem, k)
        }
        ca = compiled.cost_analysis() or {}
        rec["raw_hlo"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "note": "XLA counts scan bodies once; analytic+trip-count models used below",
        }
        # collectives: exact, from the per-partition HLO with while
        # trip-count weighting (hlo_analysis.py)
        from repro.launch.hlo_analysis import parse_collectives

        coll = parse_collectives(compiled.as_text())
        rec["collective_bytes"] = coll
        total_coll = float(sum(coll.values()))

        # compute/memory: first-principles model (launch/analytic.py)
        from repro.launch.analytic import step_counts

        shapes_p = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
        n_params_total = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(shapes_p))
        ana = step_counts(cfg, INPUT_SHAPES[shape_name], n_params_total)
        if tmsn:
            from repro.core.tmsn_sgd import TMSNSGDConfig

            # one TMSN round = K local steps per worker group
            ana = {k: v * 4 for k, v in ana.items()}  # local_steps=4
        rec["analytic"] = ana
        flops = ana["flops"]
        bytes_accessed = ana["weight_bytes"] + ana["act_bytes"] + ana["cache_bytes"]
        rec["hlo_flops"] = flops
        rec["hlo_bytes"] = bytes_accessed

        # roofline terms (per device, seconds)
        compute_t = flops / n_chips / PEAK_FLOPS_BF16
        memory_t = bytes_accessed / n_chips / HBM_BW
        coll_t = total_coll / ICI_BW
        rec["terms"] = {
            "compute_s": compute_t,
            "memory_s": memory_t,
            "collective_s": coll_t,
        }
        rec["dominant"] = max(rec["terms"], key=rec["terms"].get)

        # useful-FLOPs ratio
        seq, gb, kind = INPUT_SHAPES[shape_name]
        n_params = n_params_total
        n_active = n_params * active_param_fraction(cfg)
        tokens = gb * seq if kind != "decode" else gb
        mult = 6 if kind == "train" else 2
        model_flops = mult * n_active * tokens
        rec["model_flops"] = model_flops
        rec["useful_ratio"] = model_flops / max(flops, 1.0)
        rec["params_b"] = n_params / 1e9
        rec["status"] = "ok"
    except Exception as e:  # noqa: BLE001
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--tmsn", action="store_true", help="lower the TMSN-SGD round (train shapes)")
    ap.add_argument("--opt", action="store_true", help="apply the §Perf optimized config")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    out_dir = args.out or os.path.abspath(RESULTS_DIR)
    os.makedirs(out_dir, exist_ok=True)

    for arch in archs:
        for shape in shapes:
            if args.tmsn and INPUT_SHAPES[shape][2] != "train":
                continue
            rec = run_one(arch, shape, args.multipod, tmsn=args.tmsn, opt=args.opt)
            tag = (f"{arch}_{shape}_{rec['mesh']}" + ("_tmsn" if args.tmsn else "")
                   + ("_opt" if args.opt else ""))
            path = os.path.join(out_dir, tag + ".json")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            stat = rec["status"]
            extra = rec.get("reason", rec.get("error", ""))[:90]
            terms = rec.get("terms")
            tstr = (
                f"c={terms['compute_s']:.3e} m={terms['memory_s']:.3e} "
                f"x={terms['collective_s']:.3e} dom={rec['dominant']}"
                if terms
                else ""
            )
            print(f"[{stat:5s}] {tag:55s} {tstr} {extra}", flush=True)


if __name__ == "__main__":
    main()
