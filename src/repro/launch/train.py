"""Training driver (works on the CPU host mesh and, unchanged, on a
real pod — the mesh is the only difference).

  PYTHONPATH=src python -m repro.launch.train --arch yi-9b --steps 50 \
      --reduced --batch 8 --seq 128 [--tmsn --workers 4]

``--tmsn`` trains with the TMSN-SGD strategy (paper's protocol as the
distribution strategy) instead of synchronous data parallelism.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config, reduced
from repro.data.tokens import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import AdamWConfig, init_opt_state


def train_sync(cfg, args) -> dict:
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_cfg = AdamWConfig(lr=args.lr)
    opt_state = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(
        batch=args.batch, seq=args.seq, vocab=cfg.vocab, seed=args.seed,
        frontend_len=cfg.frontend_len if cfg.frontend else 0,
        frontend_dim=cfg.frontend_dim if cfg.frontend else 0,
    )
    losses = []
    t0 = time.time()
    for step, batch in zip(range(args.steps), pipe):
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % max(args.steps // 10, 1) == 0:
            print(f"step {step:5d} loss {loss:.4f} ({time.time()-t0:.1f}s)", flush=True)
    if args.ckpt:
        save_checkpoint(args.ckpt, params)
        print(f"saved checkpoint -> {args.ckpt}")
    return {"losses": losses, "params": params}


def train_tmsn(cfg, args) -> dict:
    from repro.core.tmsn_sgd import TMSNSGDConfig, init_tmsn_state, make_tmsn_round

    opt_cfg = AdamWConfig(lr=args.lr)
    tcfg = TMSNSGDConfig(num_workers=args.workers, local_steps=args.local_steps, eps=args.eps)
    key = jax.random.PRNGKey(args.seed)
    params_w, opt_w, cert_w = init_tmsn_state(cfg, opt_cfg, tcfg, key)
    round_fn = jax.jit(make_tmsn_round(cfg, opt_cfg, tcfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(
        batch=args.batch, seq=args.seq, vocab=cfg.vocab, seed=args.seed,
        frontend_len=cfg.frontend_len if cfg.frontend else 0,
        frontend_dim=cfg.frontend_dim if cfg.frontend else 0,
    )
    it = iter(pipe)
    W, K = tcfg.num_workers, tcfg.local_steps
    losses = []
    rounds = max(args.steps // K, 1)
    t0 = time.time()
    for r in range(rounds):
        # gather W*K batches and stack to (W, K, b, s)
        batches = [next(it) for _ in range(W * K)]
        batch_w = {
            k: jnp.stack([b[k] for b in batches]).reshape((W, K) + batches[0][k].shape)
            for k in batches[0]
        }
        params_w, opt_w, cert_w, loss = round_fn(params_w, opt_w, cert_w, batch_w)
        losses.append(float(loss))
        print(
            f"round {r:4d} mean-loss {float(loss):.4f} certs "
            f"[{float(jnp.min(cert_w)):.4f},{float(jnp.max(cert_w)):.4f}] "
            f"({time.time()-t0:.1f}s)",
            flush=True,
        )
    return {"losses": losses, "certs": np.asarray(cert_w)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--reduced", action="store_true", help="smoke-size variant (CPU)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--tmsn", action="store_true")
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--eps", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    print(f"training {cfg.name} ({'reduced' if args.reduced else 'FULL'}) "
          f"{'TMSN-SGD' if args.tmsn else 'sync-DP'}")
    if args.tmsn:
        train_tmsn(cfg, args)
    else:
        train_sync(cfg, args)


if __name__ == "__main__":
    main()
