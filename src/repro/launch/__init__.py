"""Distribution + launch: production mesh, sharding policy, pjit step
functions, multi-pod dry-run driver, trainer and server entry points."""
