"""First-principles FLOP / byte models per (arch x input shape).

Used for the compute and memory roofline terms. (XLA's cost_analysis
under-counts scanned programs — loop bodies are counted once — and its
"bytes accessed" metric is fusion-noise; collectives, by contrast, are
measured exactly from the HLO via trip-count weighting in
hlo_analysis.py. The analytic side is standard napkin-math roofline
practice: param traffic + dominant materialized intermediates.)

All results are GLOBAL (whole step, all chips); the dry-run divides by
chip count for per-device terms.
"""

from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig, LayerSpec, encoder_segments, layer_segments
from repro.models.ssm import ssm_dims


@dataclasses.dataclass
class Counts:
    flops: float = 0.0  # forward flops, global
    act_bytes: float = 0.0  # materialized intermediates (fwd), global


def _attn_layer(cfg: ArchConfig, spec: LayerSpec, b: int, s: int, s_ctx: float, cb: int) -> Counts:
    d = cfg.d_model
    hd = cfg.hd()
    H, K = cfg.num_heads, max(cfg.num_kv_heads, 1)
    T = b * s
    if cfg.attention == "mla":
        r, rd = cfg.kv_lora_rank, cfg.qk_rope_head_dim
        nd, vd = cfg.qk_nope_head_dim, cfg.v_head_dim
        proj = 2 * d * cfg.q_lora_rank + 2 * cfg.q_lora_rank * H * (nd + rd)
        proj += 2 * d * (r + rd) + 2 * H * nd * r + 2 * H * r * vd + 2 * H * vd * d
        attn = 2 * s_ctx * H * (r + rd) + 2 * s_ctx * H * r
        act = T * (H * (nd + rd) + r + rd + H * r + H * vd) * cb + b * H * s * s_ctx * 4
    else:
        proj = 2 * d * (2 * H * hd + 2 * K * hd)
        attn = 4 * s_ctx * H * hd
        act = T * (H + 2 * K) * hd * cb + b * H * s * s_ctx * 4  # qkv + f32 scores
    mlp_mats = 3 if cfg.mlp_gated else 2
    mlp = 2 * d * cfg.d_ff * mlp_mats
    act += T * cfg.d_ff * (2 if cfg.mlp_gated else 1) * cb + T * d * 4 * cb
    f = T * (proj + attn + mlp)
    if spec.cross_attention:
        f += T * (2 * d * H * hd * 2) + T * 2 * cfg.frontend_len * H * hd * 2
        act += b * H * s * cfg.frontend_len * 4
    return Counts(flops=f, act_bytes=act)


def _moe_layer(cfg: ArchConfig, spec: LayerSpec, b: int, s: int, s_ctx: float, cb: int) -> Counts:
    base = _attn_layer(cfg, LayerSpec(kind="attn"), b, s, s_ctx, cb)
    d = cfg.d_model
    fe = cfg.expert_ff()
    T = b * s
    k = cfg.num_experts_per_tok
    # subtract the dense MLP counted by _attn_layer, add router + experts
    mlp_mats = 3 if cfg.mlp_gated else 2
    base.flops -= T * 2 * d * cfg.d_ff * mlp_mats
    base.act_bytes -= T * cfg.d_ff * (2 if cfg.mlp_gated else 1) * cb
    cap_mult = cfg.capacity_factor
    base.flops += T * (2 * d * cfg.num_experts)  # router
    base.flops += T * k * cap_mult * 2 * d * fe * 3  # routed experts (padded capacity)
    base.flops += cfg.num_shared_experts * T * 2 * d * fe * 3
    base.act_bytes += T * k * cap_mult * (d + 2 * fe) * cb  # dispatch buf + hidden
    return base


def _ssm_layer(cfg: ArchConfig, b: int, s: int, cb: int) -> Counts:
    d = cfg.d_model
    di, H, P, N = ssm_dims(cfg)
    Q = min(cfg.ssm_chunk, s)
    T = b * s
    proj = 2 * d * (2 * di + 2 * N + H) + 2 * di * d
    ssd = 2 * Q * N + 2 * Q * H * P + 4 * H * N * P  # intra G, intra y, states x2
    f = T * (proj + ssd)
    # dominant intermediates: the (b, nc, Q, Q, H) decay/gate tensors (f32)
    nc = max(s // Q, 1)
    act = 3 * b * nc * Q * Q * H * 4 + T * (2 * di + 2 * N + H) * cb + T * di * cb
    return Counts(flops=f, act_bytes=act)


def _layer_counts(cfg: ArchConfig, spec: LayerSpec, b: int, s: int, s_ctx_full: float, cb: int) -> Counts:
    if spec.kind == "ssm":
        return _ssm_layer(cfg, b, s, cb)
    s_ctx = min(spec.window, s_ctx_full * 2) if spec.window else s_ctx_full
    if spec.kind == "moe":
        return _moe_layer(cfg, spec, b, s, s_ctx, cb)
    return _attn_layer(cfg, spec, b, s, s_ctx, cb)


def step_counts(cfg: ArchConfig, shape: tuple[int, int, str], n_params: int) -> dict:
    """Global FLOPs and bytes for one step of the given kind.

    Returns dict(flops, weight_bytes, act_bytes, cache_bytes).
    """
    seq, gb, kind = shape
    pb = {"float32": 4, "bfloat16": 2}[cfg.param_dtype]
    cb = {"float32": 4, "bfloat16": 2}[cfg.compute_dtype]
    if kind == "decode":
        b, s = gb, 1
        s_ctx = float(seq)  # attend over the whole cache
    elif kind == "prefill":
        b, s = gb, seq
        s_ctx = seq / 2.0  # causal average
    else:
        b, s = gb, seq
        s_ctx = seq / 2.0

    total = Counts()
    for unit, reps in layer_segments(cfg):
        for spec in unit:
            lspec = LayerSpec(kind="attn") if spec.kind == "shared_attn" else spec
            c = _layer_counts(cfg, lspec, b, s, s_ctx, cb)
            total.flops += c.flops * reps
            total.act_bytes += c.act_bytes * reps
    for unit, reps in encoder_segments(cfg):
        fl = cfg.frontend_len
        c = _attn_layer(cfg, LayerSpec(kind="attn"), b, fl, fl / 2.0, cb)
        total.flops += c.flops * reps
        total.act_bytes += c.act_bytes * reps
    # embedding + logits
    total.flops += b * s * 2 * cfg.d_model * cfg.vocab
    total.act_bytes += b * s * cfg.vocab * 4
    if cfg.mtp_depth and kind == "train":
        total.flops += b * s * (2 * cfg.d_model * cfg.d_model + 2 * cfg.d_model * cfg.vocab)
        total.act_bytes += b * s * cfg.vocab * 4

    if kind == "train":
        # fwd + backward(2x) + remat recompute (1x fwd)
        mult = 4.0 if cfg.remat else 3.0
        flops = total.flops * mult
        act_traffic = total.act_bytes * 3.0  # write fwd, read bwd, recompute
        # params: read fwd + read bwd + optimizer read/write + moments
        ob = 2 if cfg.num_experts >= 8 and cfg.d_model >= 6000 else 4
        weight_bytes = n_params * (4 * pb + 4 * ob)
        cache_bytes = 0.0
    else:
        flops = total.flops
        act_traffic = total.act_bytes
        weight_bytes = n_params * pb
        cache_bytes = 0.0
        if kind == "decode":
            cache_bytes = _decode_cache_bytes(cfg, gb, seq, cb)
    return {
        "flops": flops,
        "weight_bytes": float(weight_bytes),
        "act_bytes": act_traffic,
        "cache_bytes": cache_bytes,
    }


def _decode_cache_bytes(cfg: ArchConfig, b: int, max_len: int, cb: int) -> float:
    """Bytes read from KV caches / SSM states for ONE decode step."""
    total = 0.0
    hd = cfg.hd()
    for unit, reps in layer_segments(cfg):
        for spec in unit:
            if spec.kind == "ssm":
                di, H, P, N = ssm_dims(cfg)
                total += reps * b * H * N * P * 4 * 2  # state read+write
                continue
            s_read = min(spec.window, max_len) if spec.window else max_len
            if cfg.attention == "mla":
                total += reps * b * s_read * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) * cb
            else:
                total += reps * b * s_read * cfg.num_kv_heads * hd * 2 * cb
            if spec.cross_attention:
                total += reps * b * cfg.frontend_len * cfg.num_kv_heads * hd * 2 * cb
    return total
