"""Batched serving driver, rebuilt on the continuous-batching loop in
:mod:`repro.launch.serving`: one batched prefill, caches re-buffered
into max_len decode buffers, then per-slot decode — with the sampling
policy actually wired (``greedy`` argmax vs seeded temperature
sampling) and honest timing: both jitted step functions are compiled
during an explicit warm-up reported as ``compile_s``, so ``prefill_s``
and ``decode_s`` are steady-state numbers, and ``tok_per_s`` counts
exactly the ``batch * (gen - 1)`` decode-step tokens it divides by
(the prefill-produced first token is reported separately).

With no adoption slot the loop serves the constructor params
throughout and is bit-identical to the legacy scalar-``pos`` serve
path (pinned in tests/test_serving.py). Pass ``slot=`` to serve a
live, improving ensemble — see examples/serve_live.py.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --batch 2 --prompt-len 16 --gen 16
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.serving import (
    AdoptionSlot,
    ContinuousServer,
    Request,
    ServingConfig,
    rebuffer_caches,  # noqa: F401  — canonical home moved to serving.py
)
from repro.models import init_params


def serve(
    cfg,
    batch: int,
    prompt_len: int,
    gen: int,
    seed: int = 0,
    greedy: bool = True,
    temperature: float = 1.0,
    slot: AdoptionSlot | None = None,
):
    """Generate ``gen`` tokens (the prefill token + ``gen - 1`` decode
    steps) for ``batch`` random prompts. Returns generated tokens plus
    compile/prefill/decode timings, each measuring only what its name
    says."""
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    prompts = jax.random.randint(
        jax.random.fold_in(key, 1), (batch, prompt_len), 0, cfg.vocab, jnp.int32
    )
    frontends = [None] * batch
    if cfg.frontend:
        fe = (
            jax.random.normal(
                jax.random.fold_in(key, 2), (batch, cfg.frontend_len, cfg.frontend_dim)
            )
            * 0.02
        )
        frontends = list(np.asarray(fe, np.float32))

    scfg = ServingConfig(
        slots=batch,
        prompt_len=prompt_len,
        max_new=gen,
        greedy=greedy,
        temperature=temperature,
        seed=seed,
    )
    server = ContinuousServer(cfg, scfg, params)
    compile_s = server.warmup()
    prompts_h = np.asarray(prompts)
    requests = [
        Request(rid=i, prompt=prompts_h[i], max_new=gen, frontend=frontends[i])
        for i in range(batch)
    ]
    results, metrics = server.run(requests, slot=slot)
    gen_tokens = np.stack([r.tokens for r in results])  # (batch, gen), rid order
    return {
        "generated": gen_tokens,
        "compile_s": compile_s,
        "prefill_s": metrics["prefill_s"],
        "decode_s": metrics["decode_s"],
        # decode-only throughput over decode-only time: the prefill
        # token is in `generated` but not in either factor
        "tok_per_s": metrics["decode_tok_per_s"],
        "adoptions": metrics["adoptions"],
        "metrics": metrics,
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--sample", action="store_true", help="temperature sampling")
    ap.add_argument("--temperature", type=float, default=1.0)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    out = serve(
        cfg,
        args.batch,
        args.prompt_len,
        args.gen,
        greedy=not args.sample,
        temperature=args.temperature,
    )
    print(
        f"compile {out['compile_s']:.2f}s prefill {out['prefill_s']:.2f}s "
        f"decode {out['decode_s']:.2f}s {out['tok_per_s']:.1f} tok/s"
    )
    print("sample tokens:", out["generated"][0][:16])


if __name__ == "__main__":
    main()
