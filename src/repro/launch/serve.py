"""Batched serving driver: prefill a batch of prompts, then decode with
a re-buffered KV cache (prefill caches are copied into max_len decode
buffers). CPU-runnable on reduced configs; the same step functions are
what the dry-run lowers for the production mesh.

  PYTHONPATH=src python -m repro.launch.serve --arch yi-9b --reduced \
      --batch 2 --prompt-len 16 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.launch.steps import make_prefill_step, make_serve_step
from repro.models import init_cache, init_params
from repro.models.config import layer_segments


def rebuffer_caches(cfg, prefill_caches, batch: int, max_len: int, prompt_len: int, enc_len: int):
    """Copy prefill caches (sized to the prompt) into max_len buffers."""
    full = init_cache(cfg, batch, max_len, enc_len=enc_len)
    out = []
    for (unit, reps), seg_full, seg_pre in zip(layer_segments(cfg), full, prefill_caches):
        seg_out = []
        for spec, buf_full, buf_pre in zip(unit, seg_full, seg_pre):
            if spec.kind == "ssm":
                seg_out.append(tuple(jnp.asarray(p, b.dtype) for b, p in zip(buf_full, buf_pre)))
                continue
            entry = []
            for bi, (b_full, b_pre) in enumerate(zip(buf_full, buf_pre)):
                if b_full.shape == b_pre.shape:  # cross-attn K/V: static
                    entry.append(jnp.asarray(b_pre, b_full.dtype))
                else:  # self-attn K/V: write the prompt prefix
                    entry.append(
                        jax.lax.dynamic_update_slice_in_dim(
                            b_full, b_pre.astype(b_full.dtype), 0, axis=2
                        )
                    )
            seg_out.append(tuple(entry))
        out.append(tuple(seg_out))
    return out


def serve(cfg, batch: int, prompt_len: int, gen: int, seed: int = 0, greedy: bool = True):
    key = jax.random.PRNGKey(seed)
    params = init_params(cfg, key)
    prompts = jax.random.randint(jax.random.fold_in(key, 1), (batch, prompt_len), 0, cfg.vocab, jnp.int32)
    b = {"tokens": prompts, "labels": prompts, "mask": jnp.ones_like(prompts, jnp.float32)}
    if cfg.frontend:
        b["frontend_embeds"] = (
            jax.random.normal(jax.random.fold_in(key, 2), (batch, cfg.frontend_len, cfg.frontend_dim)) * 0.02
        )
    prefill_fn = jax.jit(make_prefill_step(cfg))
    serve_fn = jax.jit(make_serve_step(cfg), donate_argnums=(2,))

    t0 = time.time()
    next_tok, pre_caches = prefill_fn(params, b)
    max_len = prompt_len + gen
    enc_len = cfg.frontend_len if cfg.is_encdec() else 0
    caches = rebuffer_caches(cfg, pre_caches, batch, max_len, prompt_len, enc_len)
    t_prefill = time.time() - t0

    toks = [np.asarray(next_tok)]
    t0 = time.time()
    tok = next_tok
    for i in range(gen - 1):
        tok, caches = serve_fn(params, tok, caches, jnp.asarray(prompt_len + i, jnp.int32))
        toks.append(np.asarray(tok))
    t_decode = time.time() - t0
    gen_tokens = np.concatenate(toks, axis=1)
    return {
        "generated": gen_tokens,
        "prefill_s": t_prefill,
        "decode_s": t_decode,
        "tok_per_s": batch * (gen - 1) / max(t_decode, 1e-9),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    out = serve(cfg, args.batch, args.prompt_len, args.gen)
    print(f"prefill {out['prefill_s']:.2f}s decode {out['decode_s']:.2f}s "
          f"{out['tok_per_s']:.1f} tok/s")
    print("sample tokens:", out["generated"][0][:16])


if __name__ == "__main__":
    main()
