"""pjit step functions + dry-run input specs.

``input_specs`` returns ShapeDtypeStruct stand-ins (weak-type-correct,
shardable, zero allocation) for every model input per assigned input
shape — the dry-run lowers against these.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import decode_step, init_cache, loss_fn
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig, apply_updates

# The four assigned input shapes: name -> (seq_len, global_batch, kind)
INPUT_SHAPES: dict[str, tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape_name: str) -> tuple[bool, str]:
    """Is this (arch, shape) pair runnable? (the long_500k skip rule)."""
    if shape_name == "long_500k" and not cfg.supports_long_decode:
        return False, (
            "pure full-attention decode at 524288 tokens is quadratic-"
            "history/linear-per-token with an unsharded 500k KV per layer; "
            "skipped per assignment (no sliding-window/SSM variant)"
        )
    return True, ""


def batch_specs(cfg: ArchConfig, shape_name: str) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for a training/prefill batch."""
    seq, gb, kind = INPUT_SHAPES[shape_name]
    spec = {
        "tokens": jax.ShapeDtypeStruct((gb, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((gb, seq), jnp.int32),
        "mask": jax.ShapeDtypeStruct((gb, seq), jnp.float32),
    }
    if cfg.frontend is not None:
        spec["frontend_embeds"] = jax.ShapeDtypeStruct(
            (gb, cfg.frontend_len, cfg.frontend_dim), jnp.float32
        )
    return spec


def decode_specs(cfg: ArchConfig, shape_name: str) -> dict[str, Any]:
    """ShapeDtypeStructs for one decode step: token, caches, pos."""
    seq, gb, kind = INPUT_SHAPES[shape_name]
    assert kind == "decode"
    enc_len = cfg.frontend_len if cfg.is_encdec() else 0
    caches = jax.eval_shape(lambda: init_cache(cfg, gb, seq, enc_len=enc_len))
    return {
        "token": jax.ShapeDtypeStruct((gb, 1), jnp.int32),
        "caches": caches,
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_train_step(cfg: ArchConfig, opt_cfg: AdamWConfig) -> Callable:
    def train_step(params, opt_state, batch):
        def loss_only(p):
            loss, metrics = loss_fn(p, cfg, batch)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(loss_only, has_aux=True)(params)
        params, opt_state = apply_updates(params, grads, opt_state, opt_cfg)
        return params, opt_state, metrics

    return train_step


def make_prefill_step(cfg: ArchConfig) -> Callable:
    from repro.models import prefill

    def prefill_step(params, batch):
        logits, caches = prefill(params, cfg, batch)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, caches

    return prefill_step


def make_serve_step(cfg: ArchConfig) -> Callable:
    """Legacy greedy decode step (params, token, caches, pos) — kept for
    the dry-run, which lowers against the scalar-``pos`` decode specs.
    The serving loop uses :func:`make_decode_step`."""

    def serve_step(params, token, caches, pos):
        logits, caches = decode_step(params, cfg, token, caches, pos)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1)[:, None].astype(jnp.int32)
        return next_token, caches

    return serve_step


def make_decode_step(
    cfg: ArchConfig, greedy: bool = True, temperature: float = 1.0
) -> Callable:
    """Decode-step factory with an explicit sampling policy.

    ``pos`` may be a () scalar (lockstep batch) or a (b,) per-slot
    vector (continuous batching: each row decodes at its own depth).
    The returned step takes ``(params, token, caches, pos, key)``; the
    ``key`` argument is part of the signature in both modes so greedy
    and sampling traces are call-compatible (greedy ignores it).
    Sampling divides logits by ``temperature`` before a categorical
    draw — per-row independence comes from the (b,)-batched logits,
    so one key per step suffices.
    """
    if not greedy and not temperature > 0.0:
        raise ValueError(f"temperature must be > 0 for sampling, got {temperature}")

    def step(params, token, caches, pos, key):
        logits, caches = decode_step(params, cfg, token, caches, pos)
        last = logits[:, -1, :]
        if greedy:
            nxt = jnp.argmax(last, axis=-1)
        else:
            nxt = jax.random.categorical(key, last / temperature, axis=-1)
        return nxt[:, None].astype(jnp.int32), caches

    return step


def dryrun_cfg(cfg: ArchConfig) -> ArchConfig:
    """Numerics for the production lowering: bf16 params + bf16 compute
    (param_count > 100B also gets bf16 optimizer states — see dryrun)."""
    return dataclasses.replace(cfg, param_dtype="bfloat16", compute_dtype="bfloat16")


def opt_config_for(cfg: ArchConfig) -> AdamWConfig:
    # giants: bf16 Adam moments (DESIGN.md §5)
    big = cfg.num_experts >= 8 and cfg.d_model >= 6000
    return AdamWConfig(state_dtype="bfloat16" if big else "float32")
