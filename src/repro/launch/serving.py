"""Always-on serving tier: continuous batching over the live TMSN
ensemble, with zero-downtime model adoption.

The paper's core move — broadcast only on improvement, never block —
applied to the train->serve edge:

  * :class:`AdoptionSlot` is the hand-off point. The engine publishes
    best-certificate snapshots into a **double-buffered** slot
    (write-then-flip with a version counter): the writer always fills
    the inactive buffer and flips the version last, so a reader that
    re-checks the version can never observe a torn snapshot. This is
    the bounded-staleness model from ASAP (PAPERS.md): a batch may
    decode under a slightly stale snapshot, never a torn one.
  * :class:`ContinuousServer` is a request-driven serving loop with a
    slot-based continuous batcher: a fixed (slots, max_len) cache is
    allocated once; finished sequences free their row and queued
    requests claim it between decode steps (single-row prefill +
    cache insert). Each row decodes at its own position — the (b,)
    ``pos`` vector threaded through :func:`repro.models.decode_step`.
  * Adoption happens between decode steps by swapping the params
    argument of the already-compiled step functions — same shapes,
    same dtypes, so there is **no recompilation and no dropped
    request** on adoption (the elastic-membership trick, applied to
    the serving fleet). ``compile_counts()`` exposes the jit cache
    sizes so tests and benchmarks can assert the no-recompile
    property.

CPU-runnable on reduced configs; the step functions are the same ones
the dry-run lowers for the production mesh.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_cache
from repro.models.config import ArchConfig, layer_segments


# ----------------------------------------------------------------------------
# cache re-buffering: prompt-sized prefill caches -> max_len decode buffers
# ----------------------------------------------------------------------------


def rebuffer_caches(cfg, prefill_caches, batch: int, max_len: int, prompt_len: int, enc_len: int):
    """Copy prefill caches (sized to the prompt) into max_len buffers."""
    full = init_cache(cfg, batch, max_len, enc_len=enc_len)
    out = []
    for (unit, reps), seg_full, seg_pre in zip(layer_segments(cfg), full, prefill_caches):
        seg_out = []
        for spec, buf_full, buf_pre in zip(unit, seg_full, seg_pre):
            if spec.kind == "ssm":
                seg_out.append(tuple(jnp.asarray(p, b.dtype) for b, p in zip(buf_full, buf_pre)))
                continue
            entry = []
            for bi, (b_full, b_pre) in enumerate(zip(buf_full, buf_pre)):
                if b_full.shape == b_pre.shape:  # cross-attn K/V: static
                    entry.append(jnp.asarray(b_pre, b_full.dtype))
                else:  # self-attn K/V: write the prompt prefix
                    entry.append(
                        jax.lax.dynamic_update_slice_in_dim(
                            b_full, b_pre.astype(b_full.dtype), 0, axis=2
                        )
                    )
            seg_out.append(tuple(entry))
        out.append(tuple(seg_out))
    return out


# ----------------------------------------------------------------------------
# the adoption slot
# ----------------------------------------------------------------------------


class Snapshot(NamedTuple):
    """One published model: the params pytree plus its provenance."""

    version: int  # publish counter, 1-based; monotonically increasing
    params: Any  # host-side params pytree (same treedef as init_params)
    cert: float  # the certificate the snapshot was published at
    round: int  # engine round the snapshot was exported at


class AdoptionSlot:
    """Double-buffered single-slot snapshot exchange (write-then-flip).

    The writer (engine) fills the *inactive* buffer, then flips the
    version counter; the active buffer — the one ``version`` points
    readers at — is never written. A reader re-checks the version after
    copying out the buffer reference and retries if a concurrent flip
    moved it, so an :meth:`acquire` can return a stale snapshot (by at
    most the publish cadence) but never a torn one. Writers are
    serialized by a lock; readers never take it.
    """

    def __init__(self) -> None:
        self._buffers: list[tuple[Any, float, int] | None] = [None, None]
        self._version = 0  # 0 = nothing published yet
        self._write_lock = threading.Lock()
        self.publishes = 0

    @property
    def version(self) -> int:
        """Latest published version (cheap staleness probe)."""
        return self._version

    @property
    def latest_cert(self) -> float:
        """Certificate of the freshest published snapshot (nan before
        the first publish). Used for the stale-vs-fresh gap metric."""
        snap = self.acquire()
        return float("nan") if snap is None else snap.cert

    def publish(self, params: Any, cert: float, round: int = 0) -> int:
        """Write-then-flip. Returns the new version."""
        with self._write_lock:
            v = self._version + 1
            # the buffer v % 2 is inactive while version == v - 1:
            # readers are pointed at (v - 1) % 2
            self._buffers[v % 2] = (params, float(cert), int(round))
            self._version = v  # flip LAST — the publication point
            self.publishes += 1
            return v

    def acquire(self) -> Snapshot | None:
        """Latest snapshot, or None before the first publish. Never
        torn: the version is re-checked after the buffer read and the
        read retries if a flip raced it."""
        while True:
            v0 = self._version
            if v0 == 0:
                return None
            buf = self._buffers[v0 % 2]
            if self._version == v0:
                params, cert, rnd = buf
                return Snapshot(v0, params, cert, rnd)


# ----------------------------------------------------------------------------
# requests
# ----------------------------------------------------------------------------


@dataclasses.dataclass
class Request:
    """One generation request. ``prompt`` must be (prompt_len,) int —
    the batcher keeps fixed shapes, so all requests share the server's
    prompt length. ``max_new`` counts generated tokens *including* the
    prefill-produced first token; it must be in [1, cfg.max_new]."""

    rid: int
    prompt: np.ndarray
    max_new: int
    frontend: np.ndarray | None = None  # (frontend_len, frontend_dim)


@dataclasses.dataclass
class RequestResult:
    rid: int
    tokens: np.ndarray  # (n_generated,) int32, prefill token first
    latency_s: float  # queue entry -> last token
    versions: tuple[int, ...]  # snapshot versions this request decoded under


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """Continuous-batcher shape and sampling policy. All shapes are
    fixed at construction — admission and adoption never retrace."""

    slots: int  # concurrent sequences (the fixed batch dimension)
    prompt_len: int
    max_new: int  # per-request cap; sets max_len = prompt_len + max_new
    greedy: bool = True
    temperature: float = 1.0
    seed: int = 0
    #: check the adoption slot every N decode steps (1 = every step);
    #: larger values trade staleness for fewer host version probes
    adopt_every: int = 1

    def __post_init__(self) -> None:
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.prompt_len < 1:
            raise ValueError(f"prompt_len must be >= 1, got {self.prompt_len}")
        if self.max_new < 1:
            raise ValueError(f"max_new must be >= 1, got {self.max_new}")
        if self.adopt_every < 1:
            raise ValueError(f"adopt_every must be >= 1, got {self.adopt_every}")
        if not self.greedy and not self.temperature > 0.0:
            raise ValueError(
                f"temperature must be > 0 for sampling, got {self.temperature}"
            )


# ----------------------------------------------------------------------------
# the server
# ----------------------------------------------------------------------------


class ContinuousServer:
    """Slot-based continuous batcher over fixed-shape decode buffers.

    Two jitted entry points, both warmed once by :meth:`warmup`:

      * prefill — traced at (slots, prompt_len) for the batched
        bootstrap and at (1, prompt_len) for mid-run admission;
      * decode — one trace at (slots,) per-row positions, params passed
        as an argument so adoption is a pure data swap.

    A no-publish run (``slot=None``, all requests admitted at start,
    equal lengths) decodes in lockstep — every row of the (b,) position
    vector equal — and is bit-identical to the legacy scalar-``pos``
    serve loop (pinned in tests/test_serving.py).
    """

    def __init__(self, cfg: ArchConfig, scfg: ServingConfig, params: Any) -> None:
        self.cfg = cfg
        self.scfg = scfg
        self.params = jax.device_put(params)
        self.enc_len = cfg.frontend_len if cfg.is_encdec() else 0
        self.max_len = scfg.prompt_len + scfg.max_new
        self._prefill = jax.jit(make_prefill_step(cfg))
        self._decode = jax.jit(
            make_decode_step(cfg, greedy=scfg.greedy, temperature=scfg.temperature),
            donate_argnums=(2,),
        )
        self._insert = jax.jit(_insert_row, donate_argnums=(0,))
        self._key = jax.random.PRNGKey(scfg.seed)
        self.adopted_version = 0  # 0 = serving the constructor params
        self.served_cert = float("nan")
        self.adoptions = 0
        self._warmed = False

    # -- plumbing -----------------------------------------------------------

    def _batchify(self, prompts: list[np.ndarray], frontends: list) -> dict:
        toks = jnp.asarray(np.stack(prompts).astype(np.int32))
        b = {
            "tokens": toks,
            "labels": toks,
            "mask": jnp.ones_like(toks, jnp.float32),
        }
        if self.cfg.frontend is not None:
            fes = [
                np.zeros((self.cfg.frontend_len, self.cfg.frontend_dim), np.float32)
                if fe is None
                else np.asarray(fe, np.float32)
                for fe in frontends
            ]
            b["frontend_embeds"] = jnp.asarray(np.stack(fes))
        return b

    def compile_counts(self) -> dict[str, int]:
        """jit-cache sizes of the serving-path entry points — the
        no-recompile-after-warmup assertion reads these."""
        return {
            "prefill": self._prefill._cache_size(),
            "decode": self._decode._cache_size(),
            "insert": self._insert._cache_size(),
        }

    def warmup(self) -> float:
        """Compile every serving-path trace on dummy inputs; returns
        the wall time spent (reported as ``compile_s``). Idempotent."""
        t0 = time.perf_counter()
        B, P = self.scfg.slots, self.scfg.prompt_len
        zeros = [np.zeros(P, np.int32) for _ in range(B)]
        nones = [None] * B
        tok, pre = self._prefill(self.params, self._batchify(zeros, nones))
        caches = rebuffer_caches(self.cfg, pre, B, self.max_len, P, self.enc_len)
        _, pre1 = self._prefill(self.params, self._batchify(zeros[:1], nones[:1]))
        caches = self._insert(caches, pre1, jnp.asarray(0, jnp.int32))
        pos = np.full((B,), P, np.int32)
        tok, caches = self._decode(
            self.params, tok, caches, jnp.asarray(pos), self._key
        )
        jax.block_until_ready(tok)
        self._warmed = True
        return time.perf_counter() - t0

    def adopt(self, slot: AdoptionSlot) -> bool:
        """Adopt the newest published snapshot if it is fresher than
        the one being served. Returns True on an actual swap."""
        if slot.version == self.adopted_version:
            return False
        snap = slot.acquire()
        if snap is None or snap.version == self.adopted_version:
            return False
        self.params = jax.device_put(snap.params)
        self.adopted_version = snap.version
        self.served_cert = snap.cert
        self.adoptions += 1
        return True

    # -- the loop -----------------------------------------------------------

    def run(
        self,
        requests: list[Request],
        slot: AdoptionSlot | None = None,
        step_hook: Callable[["ContinuousServer", int], None] | None = None,
    ) -> tuple[list[RequestResult], dict]:
        """Serve ``requests`` to completion. All requests are queued at
        t=0; admission is continuous (freed slots are re-claimed between
        decode steps). Returns (results sorted by rid, metrics)."""
        scfg = self.scfg
        B, P = scfg.slots, scfg.prompt_len
        for r in requests:
            if not 1 <= r.max_new <= scfg.max_new:
                raise ValueError(
                    f"request {r.rid}: max_new must be in [1, {scfg.max_new}], "
                    f"got {r.max_new}"
                )
            if np.shape(r.prompt) != (P,):
                raise ValueError(
                    f"request {r.rid}: prompt must be ({P},), got {np.shape(r.prompt)}"
                )
        counts0 = self.compile_counts() if self._warmed else None
        pending = deque(requests)
        results: list[RequestResult] = []

        active = [False] * B
        req_of: list[Request | None] = [None] * B
        toks: list[list[int]] = [[] for _ in range(B)]
        versions: list[set[int]] = [set() for _ in range(B)]
        pos_h = np.zeros((B,), np.int32)
        tok_h = np.zeros((B, 1), np.int32)

        step_wall: list[float] = []
        adoption_steps: list[int] = []
        cert_gaps: list[float] = []
        prefill_s = 0.0

        t_run0 = time.perf_counter()

        def retire(s: int) -> None:
            req = req_of[s]
            results.append(
                RequestResult(
                    rid=req.rid,
                    tokens=np.asarray(toks[s], np.int32),
                    latency_s=time.perf_counter() - t_run0,
                    versions=tuple(sorted(versions[s])),
                )
            )
            active[s] = False
            req_of[s] = None

        def bookkeep_admit(s: int, req: Request, first_tok: int) -> None:
            active[s] = True
            req_of[s] = req
            toks[s] = [first_tok]
            versions[s] = {self.adopted_version}
            pos_h[s] = P
            tok_h[s, 0] = first_tok
            if len(toks[s]) >= req.max_new:
                retire(s)

        # batched bootstrap: a full first wave prefills in one call —
        # the same batched-prefill + rebuffer path as the legacy serve
        t0 = time.perf_counter()
        if len(pending) >= B:
            wave = [pending.popleft() for _ in range(B)]
            bdict = self._batchify([r.prompt for r in wave], [r.frontend for r in wave])
            ntok, pre = self._prefill(self.params, bdict)
            caches = rebuffer_caches(self.cfg, pre, B, self.max_len, P, self.enc_len)
            ntok_h = np.asarray(ntok)
            for s, r in enumerate(wave):
                bookkeep_admit(s, r, int(ntok_h[s, 0]))
        else:
            caches = init_cache(self.cfg, B, self.max_len, enc_len=self.enc_len)
        prefill_s += time.perf_counter() - t0

        step = 0
        while True:
            # admission: freed slots claim queued requests (single-row
            # prefill + in-place cache insert; fixed shapes throughout)
            for s in range(B):
                while not active[s] and pending:
                    req = pending.popleft()
                    t0 = time.perf_counter()
                    bdict = self._batchify([req.prompt], [req.frontend])
                    ntok1, pre1 = self._prefill(self.params, bdict)
                    caches = self._insert(caches, pre1, jnp.asarray(s, jnp.int32))
                    prefill_s += time.perf_counter() - t0
                    bookkeep_admit(s, req, int(np.asarray(ntok1)[0, 0]))
            if not any(active):
                break

            # adoption between decode steps: a cheap version probe, then
            # a torn-read-safe acquire only when the slot moved
            adopted = False
            if slot is not None and step % scfg.adopt_every == 0:
                adopted = self.adopt(slot)
            if slot is not None:
                fresh = slot.latest_cert
                if np.isfinite(self.served_cert) and np.isfinite(fresh):
                    cert_gaps.append(self.served_cert - fresh)

            t0 = time.perf_counter()
            key = jax.random.fold_in(self._key, step)
            tok_d, caches = self._decode(
                self.params, jnp.asarray(tok_h), caches, jnp.asarray(pos_h), key
            )
            # host sync: completions are decided here (np.array copies —
            # admission writes fresh first-tokens into freed rows)
            tok_h = np.array(tok_d)
            step_wall.append(time.perf_counter() - t0)
            if adopted:
                adoption_steps.append(step)

            for s in range(B):
                if not active[s]:
                    continue
                toks[s].append(int(tok_h[s, 0]))
                versions[s].add(self.adopted_version)
                pos_h[s] += 1
                if len(toks[s]) >= req_of[s].max_new:
                    retire(s)
            step += 1
            if step_hook is not None:
                step_hook(self, step)

        wall_s = time.perf_counter() - t_run0
        results.sort(key=lambda r: r.rid)
        decode_tok = sum(len(r.tokens) - 1 for r in results)
        latencies = np.asarray([r.latency_s for r in results] or [0.0])
        walls_ms = np.asarray(step_wall or [0.0]) * 1e3
        adopt_ms = np.asarray([step_wall[i] for i in adoption_steps] or [0.0]) * 1e3
        steady = [w for i, w in enumerate(step_wall) if i not in set(adoption_steps)]
        steady_ms = np.asarray(steady or [0.0]) * 1e3
        counts1 = self.compile_counts()
        metrics = {
            "wall_s": wall_s,
            "requests_completed": len(results),
            "dropped_requests": len(requests) - len(results),
            "req_per_s": len(results) / max(wall_s, 1e-9),
            "latency_p50_s": float(np.percentile(latencies, 50)),
            "latency_p99_s": float(np.percentile(latencies, 99)),
            "decode_steps": step,
            "decode_tokens": decode_tok,
            "prefill_s": prefill_s,
            "decode_s": float(np.sum(step_wall)),
            "decode_tok_per_s": decode_tok / max(float(np.sum(step_wall)), 1e-9),
            "step_p50_ms": float(np.percentile(walls_ms, 50)),
            "step_p99_ms": float(np.percentile(walls_ms, 99)),
            "adoptions": self.adoptions,
            "adoption_steps": list(adoption_steps),
            "adoption_blip_p99_ms": float(np.percentile(adopt_ms, 99)),
            "steady_step_p99_ms": float(np.percentile(steady_ms, 99)),
            "stale_cert_gap_mean": float(np.mean(cert_gaps)) if cert_gaps else 0.0,
            "stale_cert_gap_max": float(np.max(cert_gaps)) if cert_gaps else 0.0,
            "recompiles": (
                sum(counts1.values()) - sum(counts0.values())
                if counts0 is not None
                else None
            ),
        }
        return results, metrics


def _insert_row(caches, pre_caches, row):
    """Write a single prefilled request (batch-1 prefill caches) into
    row ``row`` of the full decode buffers.

    One rule covers every cache kind: the batch-1 block is
    dynamic-update-sliced at (0, row, 0, ...), which is a full row
    overwrite for SSM state / conv tails / cross-attn K/V (shapes match
    except batch) and a prompt-prefix write for self-attn K/V (the pre
    block is shorter along the seq axis). Stale entries beyond the
    prefix belong to the row's previous occupant and sit at key
    positions > the new request's positions, so the causal mask hides
    them until they are overwritten.
    """

    def one(b_full, b_pre):
        start = (jnp.asarray(0, jnp.int32), row) + (jnp.asarray(0, jnp.int32),) * (
            b_full.ndim - 2
        )
        return jax.lax.dynamic_update_slice(b_full, b_pre.astype(b_full.dtype), start)

    return jax.tree.map(one, caches, pre_caches)
