"""Batched serving example: prefill a batch of prompts through a
reduced zoo member, re-buffer the KV caches, and decode tokens — the
same ``prefill_step`` / ``serve_step`` the production dry-run lowers.

  PYTHONPATH=src python examples/serve_batch.py [--arch gemma3-12b]
"""

import argparse

from repro.configs import get_config, reduced
from repro.launch.serve import serve


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-12b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = reduced(get_config(args.arch))
    print(f"serving reduced {cfg.name}: batch={args.batch} "
          f"prompt={args.prompt_len} gen={args.gen}")
    out = serve(cfg, args.batch, args.prompt_len, args.gen)
    print(f"compile {out['compile_s']:.2f}s (one-time); "
          f"prefill {out['prefill_s']:.2f}s; decode {out['decode_s']:.2f}s "
          f"({out['tok_per_s']:.1f} tok/s)")
    print("first request's generations:", out["generated"][0][:12], "...")


if __name__ == "__main__":
    main()
