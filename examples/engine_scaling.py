"""The round-based engine at paper scale: 64 TMSN workers in one jit.

The event-driven simulator (examples/quickstart.py) dispatches one
small JAX call per worker segment — faithful, but interpreter-bound
past ~16 workers. The vectorized engine advances ALL workers one
segment per round inside a single jitted computation, so worker counts
the paper actually cares about (hundreds of machines, laggards and
failures that only matter at scale) run on this laptop-class CPU.

  PYTHONPATH=src python examples/engine_scaling.py
"""

import time

import numpy as np

from repro.boosting import BatchedSparrowWorker, SparrowConfig
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import error_rate, exp_loss
from repro.core.engine import EngineConfig, TMSNEngine, quantize_latency
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split


def main() -> None:
    # d >= W so feature ownership (j mod W) gives every worker features
    xb, y, _ = make_splice_like(SpliceConfig(n=30_000, d=128, num_bins=8, seed=7))
    xtr, ytr, xte, yte = train_test_split(xb, y)
    print(f"data: {xtr.shape[0]} train / {xte.shape[0]} test, d={xtr.shape[1]}")

    w = 64
    cfg = SparrowConfig(
        sample_size=1024,
        capacity=64,
        scanner=ScannerConfig(chunk_size=256, num_bins=8, gamma0=0.25),
        n_workers=w,
    )
    worker = BatchedSparrowWorker(xtr, ytr, cfg)

    # heterogeneous cluster: a 10x laggard, one mid-run failure, jittered
    # link latencies quantized to round delays
    speed = np.ones(w)
    speed[-1] = 0.1
    fail_round = np.full(w, 10**6)
    fail_round[w // 2] = 60
    delays = quantize_latency(0.05, 0.02, round_dt=0.05, n_workers=w, seed=1)

    eng = TMSNEngine(
        worker,
        EngineConfig(
            n_workers=w,
            delay_rounds=delays,
            speed=speed,
            fail_round=fail_round,
            max_rounds=150,
            seed=0,
        ),
    )
    t0 = time.time()
    res = eng.run()
    wall = time.time() - t0

    certs = np.asarray(res.final_certificates)
    best = int(np.argmin(certs))
    model = res.final_models[best]
    print(
        f"[engine x{w}] rounds={res.rounds}  wall={wall:.1f}s "
        f"({1e3 * wall / max(res.rounds, 1):.0f} ms/round, all {w} workers)"
    )
    print(
        f"  loss={float(exp_loss(model, xte, yte)):.4f} "
        f"err={float(error_rate(model, xte, yte)):.4f} "
        f"best_cert={certs[best]:.4f}"
    )
    live = [c for i, c in enumerate(certs) if i != w // 2]
    print(
        f"  cohort spread={max(live) - min(live):.4f}  "
        f"msgs sent={res.messages_sent} accepted={res.messages_accepted} "
        f"discarded={res.messages_discarded}"
    )


if __name__ == "__main__":
    main()
