"""Quickstart: TMSN + Sparrow in 60 seconds.

Trains boosted decision stumps on a synthetic splice-site-like task
three ways — single Sparrow worker, 4 TMSN workers (one a 10x
laggard!), and the XGBoost-style full-scan baseline — and prints the
loss each reaches per unit of simulated wall-clock.

  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.boosting import BoosterConfig, SparrowConfig, SparrowWorker, train_exact_greedy
from repro.boosting.scanner import ScannerConfig
from repro.boosting.stumps import error_rate, exp_loss
from repro.core.simulator import SimulatorConfig, TMSNSimulator, WorkerSpec
from repro.data.splice import SpliceConfig, make_splice_like, train_test_split


def main() -> None:
    xb, y, _ = make_splice_like(SpliceConfig(n=30_000, d=32, num_bins=8, seed=7))
    xtr, ytr, xte, yte = train_test_split(xb, y)
    print(f"data: {xtr.shape[0]} train / {xte.shape[0]} test, d={xtr.shape[1]}")

    # --- XGBoost-style baseline: full scan every round ---
    tr = train_exact_greedy(
        xtr, ytr, BoosterConfig(num_rounds=25, num_bins=8, eval_every=24),
        eval_fn=lambda m: float(exp_loss(m, xte, yte)),
    )
    print(f"[exact-greedy ] loss={tr.metric[-1]:.4f}  cost={tr.cost[-1]:.2e} example-reads")

    # --- Sparrow workers under TMSN (worker 3 is a 10x laggard) ---
    for nw, specs in [
        (1, [WorkerSpec()]),
        (4, [WorkerSpec(), WorkerSpec(), WorkerSpec(), WorkerSpec(speed=0.1)]),
    ]:
        cfg = SparrowConfig(
            sample_size=3072, capacity=96,
            scanner=ScannerConfig(chunk_size=1024, num_bins=8, gamma0=0.25),
            n_workers=nw,
        )
        sim = TMSNSimulator(
            SparrowWorker(xtr, ytr, cfg), specs,
            SimulatorConfig(n_workers=nw, max_events=700 * nw, eps=0.0),
        )
        res = sim.run()
        best = int(np.argmin(res.final_certificates))
        model = res.final_models[best]
        print(
            f"[sparrow x{nw}   ] loss={float(exp_loss(model, xte, yte)):.4f} "
            f"err={float(error_rate(model, xte, yte)):.4f} "
            f"sim_time={res.sim_time:.2e}  msgs={res.messages_sent} "
            f"accepted={res.messages_accepted}"
        )


if __name__ == "__main__":
    main()
