"""Train-while-serving: the always-on serving tier adopting a live,
improving TMSN ensemble with zero downtime.

A :class:`~repro.core.engine.TMSNEngine` trains a tiny transformer
ensemble in a background thread with a publisher attached
(``publish_every_k=1``): whenever the ensemble's best certificate
strictly improves at a round boundary, the engine snapshots the
winning worker's params into the shared
:class:`~repro.launch.serving.AdoptionSlot` (double-buffered
write-then-flip — readers never see a torn snapshot, only the previous
complete one).

Meanwhile the foreground :class:`~repro.launch.serving.ContinuousServer`
decodes a stream of requests and, between decode steps, adopts whatever
the newest snapshot is — no recompilation (params are jit arguments),
no dropped requests, no pause. Requests that span an adoption finish
under newer weights than they started with; the printout shows each
adoption event and the certificate it moved the serving tier to.

  PYTHONPATH=src python examples/serve_live.py [--rounds 24]
"""

from __future__ import annotations

import argparse
import threading
import time

import numpy as np

import jax

from repro.core.engine import EngineConfig, TMSNEngine
from repro.core.sgd_worker import lm_sgd_worker
from repro.core.tmsn_sgd import TMSNSGDConfig
from repro.launch.serving import AdoptionSlot, ContinuousServer, Request, ServingConfig
from repro.models import init_params
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig

ARCH = ArchConfig(
    name="serve-live",
    arch_type="llama",
    num_layers=2,
    d_model=32,
    num_heads=2,
    num_kv_heads=2,
    d_ff=64,
    vocab=128,
    remat=False,
    compute_dtype="float32",
)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=48)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument(
        "--pace",
        type=float,
        default=0.05,
        help="seconds slept per decode step; at this toy scale decode is "
        "~1ms/step while a training round is ~10-100ms, so an unpaced "
        "server would drain the whole request stream between two "
        "publishes — pacing keeps the demo's serving window open across "
        "several of them (set 0 for raw speed)",
    )
    args = ap.parse_args()

    slot = AdoptionSlot()
    worker = lm_sgd_worker(
        ARCH,
        AdamWConfig(lr=1e-2),
        TMSNSGDConfig(local_steps=2, ema=0.8, width_coef=1.0),
        batch_size=2,
        seq=16,
    )
    engine = TMSNEngine(
        worker,
        EngineConfig(
            n_workers=4,
            eps=0.0,
            max_rounds=args.rounds,
            seed=0,
            record_history=False,
            publish_every_k=1,
            rounds_per_dispatch=1,
        ),
    )
    engine.attach_publisher(slot)

    # warm up the serving tier on freshly-initialised weights BEFORE
    # training starts (real deployments warm the server once at boot;
    # here it also keeps the ~seconds-scale compile from eating the
    # whole training run)
    scfg = ServingConfig(
        slots=args.slots, prompt_len=8, max_new=12, seed=0, adopt_every=1
    )
    server = ContinuousServer(ARCH, scfg, init_params(ARCH, jax.random.PRNGKey(7)))
    print(f"warm-up compile: {server.warmup():.2f}s (one-time)")

    trainer = threading.Thread(target=engine.run, name="tmsn-trainer")
    trainer.start()
    # open the serving window only once the trainer is actually
    # publishing — its first round carries the engine's own one-time
    # compile, which would otherwise outlast the whole request stream
    while slot.version == 0:
        time.sleep(0.01)
    print(f"first snapshot published (cert {slot.latest_cert:.4f}); serving begins")

    def on_step(srv: ContinuousServer, step: int) -> None:
        # report each adoption as it happens (run() already adopted
        # this step if a newer snapshot was available)
        if srv.adopted_version != on_step.seen:
            on_step.seen = srv.adopted_version
            print(
                f"  step {step:3d}: adopted v{srv.adopted_version} "
                f"(cert {srv.served_cert:.4f}); in-flight requests continue"
            )
        if args.pace:
            time.sleep(args.pace)

    on_step.seen = 0

    rng = np.random.default_rng(0)
    requests = [
        Request(
            rid=i,
            prompt=rng.integers(0, ARCH.vocab, 8).astype(np.int32),
            max_new=4 + (i % 9),
        )
        for i in range(args.requests)
    ]
    results, metrics = server.run(requests, slot=slot, step_hook=on_step)
    trainer.join()

    multi = sum(1 for r in results if len(r.versions) > 1)
    print(
        f"served {metrics['requests_completed']} requests "
        f"({metrics['dropped_requests']} dropped) across "
        f"{metrics['adoptions']} live adoptions, "
        f"{metrics['recompiles']} recompiles after warm-up"
    )
    print(
        f"{multi} requests decoded under more than one snapshot; "
        f"final serving cert {server.served_cert:.4f} vs first adopted; "
        f"stale-gap mean {metrics['stale_cert_gap_mean']:.5f}"
    )


if __name__ == "__main__":
    main()
