"""The device-sharded TMSN engine: 256 workers over 8 devices.

examples/engine_scaling.py keeps all workers on ONE device — the round
math is vectorized but the paper's deployment (independent machines
exchanging only "something new") is still simulated. This example runs
the same protocol with the worker state physically partitioned over a
``workers`` mesh axis: each device advances 32 of the 256 workers per
round, and the only cross-device traffic is one all_gather of the
round's certificates and model payloads (reported below as gossip
bytes/round — the number that would hit a real interconnect).

Final certificates are IDENTICAL to the single-device engine on the
same config (tests/test_sharded_engine.py pins this), so sharding is
purely an execution-substrate choice.

The last section goes one rung up the hierarchy: the same 8 devices as
a two-tier ``(pod=2, workers=4)`` mesh, where intra-pod gossip stays
per-round but cross-pod payloads move only every 8th round — the
engine reports the resulting ICI vs DCN traffic split.

  PYTHONPATH=src python examples/engine_sharded.py
"""

import os

# appended last (XLA flag parsing is last-wins) and before the first
# jax import: fake 8 devices so the engine has something to shard over
os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_device_count=8"
).strip()

import time

import numpy as np

from repro.boosting import BatchedSparrowWorker, SparrowConfig
from repro.boosting.scanner import ScannerConfig
from repro.core.engine import EngineConfig, make_engine, quantize_latency
from repro.launch.mesh import make_worker_mesh


def main() -> None:
    import jax

    from repro.data.splice import SpliceConfig, make_splice_like, train_test_split

    print(f"devices: {jax.device_count()} ({jax.default_backend()})")

    # d >= W so feature ownership (j mod W) gives every worker features
    xb, y, _ = make_splice_like(SpliceConfig(n=30_000, d=256, num_bins=8, seed=7))
    xtr, ytr, xte, yte = train_test_split(xb, y)
    print(f"data: {xtr.shape[0]} train / {xte.shape[0]} test, d={xtr.shape[1]}")

    w = 256
    cfg = SparrowConfig(
        sample_size=512,
        capacity=48,
        scanner=ScannerConfig(chunk_size=256, num_bins=8, gamma0=0.25),
        n_workers=w,
    )
    worker = BatchedSparrowWorker(xtr, ytr, cfg)

    # heterogeneous cluster: a 10x laggard, one mid-run failure, jittered
    # link latencies quantized to round delays — all sharded
    speed = np.ones(w)
    speed[-1] = 0.1
    fail = np.full(w, 10**6)
    fail[-2] = 40
    delays = quantize_latency(0.05, 0.02, 0.05, w, seed=1)

    mesh = make_worker_mesh()
    eng = make_engine(
        worker,
        EngineConfig(
            n_workers=w,
            delay_rounds=delays,
            speed=speed,
            fail_round=fail,
            max_rounds=80,
            seed=0,
            record_history=False,
            mesh=mesh,
            # pinned: this run is the dense baseline for the gated
            # comparison below, even under REPRO_GOSSIP_MODE=gated
            gossip_mode="dense",
        ),
    )
    print(f"engine: {type(eng).__name__}, {w} workers / {mesh.shape['workers']} devices "
          f"= {w // mesh.shape['workers']} per shard")

    t0 = time.time()
    res = eng.run()
    wall = time.time() - t0

    certs = np.asarray(res.final_certificates)
    live = np.ones(w, bool)
    live[-2] = False
    print(f"\n{res.rounds} rounds in {wall:.1f}s "
          f"({1e3 * wall / max(res.rounds, 1):.0f} ms/round incl. compile)")
    print(f"best certificate: {certs.min():.4f}  "
          f"(cohort spread among survivors: {certs[live].max() - certs[live].min():.4f})")
    print(f"broadcasts: {res.messages_sent}, adoptions: {res.messages_accepted}, "
          f"payload bytes: {res.bytes_broadcast:,}")
    print(f"gossip per round: {res.gossip_bytes_per_round:,} bytes "
          f"({res.gossip_bytes_per_round * res.rounds / 1e6:.1f} MB total all_gather traffic)")

    # same run with the improvement gate applied to the interconnect:
    # certificates still all_gather densely (W·5 bytes of control
    # plane), but model payloads move only for each device's best
    # locally-improved candidate — O(n_dev·payload) instead of
    # O(W·payload). The delays here are heterogeneous, so this is the
    # engine's explicit approximation mode: compare the best
    # certificates, not just the traffic.
    eng_gated = make_engine(
        BatchedSparrowWorker(xtr, ytr, cfg),
        EngineConfig(
            n_workers=w,
            delay_rounds=delays,
            speed=speed,
            fail_round=fail,
            max_rounds=80,
            seed=0,
            record_history=False,
            mesh=mesh,
            gossip_mode="gated",
        ),
    )
    t0 = time.time()
    res_g = eng_gated.run()
    wall_g = time.time() - t0
    certs_g = np.asarray(res_g.final_certificates)
    print(f"\ngated gossip: {res_g.rounds} rounds in {wall_g:.1f}s, "
          f"{res_g.gossip_bytes_per_round:,} bytes/round "
          f"({res.gossip_bytes_per_round / res_g.gossip_bytes_per_round:.0f}x less wire traffic)")
    print(f"best certificate: {certs_g.min():.4f} vs {certs.min():.4f} dense "
          f"(heterogeneous delays: approximation, measured not assumed)")

    # one rung up the hierarchy: the same 8 devices as two pods of 4.
    # Intra-pod gossip stays the per-round all_gather (ICI); cross-pod
    # payloads accumulate in a pending tier and only each device's
    # freshest improved certificate crosses the DCN every 8th round.
    # At cross_pod_every_k=1 this is bit-identical to the flat engine
    # (pinned in tests); at k=8 it is the approximation that buys the
    # DCN its ~8x quiet — compare the best certificates below.
    pod_mesh = make_worker_mesh(pods=2)
    eng_pod = make_engine(
        BatchedSparrowWorker(xtr, ytr, cfg),
        EngineConfig(
            n_workers=w,
            delay_rounds=delays,
            speed=speed,
            fail_round=fail,
            max_rounds=80,
            seed=0,
            record_history=False,
            mesh=pod_mesh,
            gossip_mode="gated",
            cross_pod_every_k=8,
            cross_pod_top_k=1,
        ),
    )
    t0 = time.time()
    res_p = eng_pod.run()
    wall_p = time.time() - t0
    certs_p = np.asarray(res_p.final_certificates)
    print(f"\npod mesh (2 pods x {pod_mesh.shape['workers']} devices, cross-pod every 8 rounds): "
          f"{res_p.rounds} rounds in {wall_p:.1f}s")
    print(f"traffic tiers: {res_p.gossip_bytes_per_round_ici:,} B/round intra-pod (ICI) + "
          f"{res_p.gossip_bytes_per_round_dcn:,} B/round cross-pod (DCN, amortized)")
    print(f"cross-pod pushes: {res_p.messages_sent_dcn} of {res_p.messages_sent} total")
    print(f"best certificate: {certs_p.min():.4f} vs {certs_g.min():.4f} single-tier gated "
          f"(staleness is measured, not assumed)")


if __name__ == "__main__":
    main()
