"""TMSN-SGD on a small LM: 4 worker groups train with independent local
steps and exchange parameters only when one's certificate beats the
others by eps — the paper's protocol as a neural-net distribution
strategy (DESIGN.md §3, level 3). Compares against synchronous DP on
identical data.

  PYTHONPATH=src python examples/tmsn_sgd_lm.py [--rounds 10]
"""

import argparse
import time

import jax

from repro.configs import get_config, reduced
from repro.core.tmsn_sgd import TMSNSGDConfig, init_tmsn_state, make_tmsn_round
from repro.data.tokens import synthetic_token_batch
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.optim import AdamWConfig, init_opt_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    args = ap.parse_args()

    cfg = reduced(get_config("yi-9b"))
    opt_cfg = AdamWConfig(lr=1e-3)
    W, K, b, s = args.workers, args.local_steps, 4, 64
    key = jax.random.PRNGKey(0)

    # sync baseline on the same token stream
    params = init_params(cfg, key)
    opt = init_opt_state(params, opt_cfg)
    sync = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))
    kb = key
    for i in range(args.rounds * K):
        kb = jax.random.fold_in(kb, i)
        params, opt, m = sync(params, opt, synthetic_token_batch(kb, b * W, s, cfg.vocab))
    print(f"[sync-DP ] final loss {float(m['loss']):.4f} "
          f"({args.rounds * K} steps, {W * K * args.rounds} gradient all-reduces)")

    # TMSN-SGD
    tcfg = TMSNSGDConfig(num_workers=W, local_steps=K, eps=0.01)
    params_w, opt_w, cert_w = init_tmsn_state(cfg, opt_cfg, tcfg, key)
    round_fn = jax.jit(make_tmsn_round(cfg, opt_cfg, tcfg), donate_argnums=(0, 1))
    kb = jax.random.fold_in(key, 10_000)
    t0 = time.time()
    for r in range(args.rounds):
        kb = jax.random.fold_in(kb, r)
        batch = synthetic_token_batch(kb, W * K * b, s, cfg.vocab)
        batch_w = {k: v.reshape((W, K, b) + v.shape[1:]) for k, v in batch.items()}
        params_w, opt_w, cert_w, loss = round_fn(params_w, opt_w, cert_w, batch_w)
        print(f"[TMSN-SGD] round {r}: loss {float(loss):.4f} "
              f"certs {[round(float(c), 3) for c in cert_w]}")
    print(f"[TMSN-SGD] {args.rounds} param exchanges instead of "
          f"{args.rounds * K} gradient all-reduces ({time.time()-t0:.1f}s)")


if __name__ == "__main__":
    main()
