"""TMSN-SGD on a small LM, hosted by the gossip engine: transformer
workers run K local AdamW steps per round and broadcast parameters only
on strict certificate improvement — the paper's protocol as a
neural-net distribution strategy, driven end-to-end by the same
``TMSNEngine`` that runs the boosting workers (laggards, failures, and
round latencies included).

  PYTHONPATH=src python examples/tmsn_sgd_lm.py [--rounds 12] [--laggard]

On a multi-device host (or XLA_FLAGS=--xla_force_host_platform_device_count=8)
add ``--mesh`` to run the identical protocol through the shard-mapped
``ShardedTMSNEngine`` instead — same final certificates, real
collectives.
"""

import argparse
import time

import numpy as np

from repro.core.engine import EngineConfig, make_engine
from repro.core.sgd_worker import lm_sgd_worker
from repro.core.tmsn_sgd import TMSNSGDConfig, oracle_run
from repro.models.config import ArchConfig
from repro.optim import AdamWConfig


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument(
        "--laggard",
        action="store_true",
        help="run worker 0 at quarter speed (one segment every 4 rounds)",
    )
    ap.add_argument(
        "--mesh",
        action="store_true",
        help="shard the worker axis over all visible devices",
    )
    args = ap.parse_args()

    arch = ArchConfig(
        name="example-lm",
        arch_type="llama",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab=256,
        remat=False,
        compute_dtype="float32",
    )
    W, K = args.workers, args.local_steps
    worker = lm_sgd_worker(
        arch,
        AdamWConfig(lr=1e-2),
        TMSNSGDConfig(local_steps=K, ema=0.9, width_coef=1.0),
        batch_size=4,
        seq=32,
    )

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_worker_mesh

        mesh = make_worker_mesh()
    speed = [0.25] + [1.0] * (W - 1) if args.laggard else None
    cfg = EngineConfig(
        n_workers=W,
        eps=0.0,
        max_rounds=args.rounds,
        delay_rounds=1,
        speed=speed,
        seed=0,
        mesh=mesh,
    )
    eng = make_engine(worker, cfg)
    t0 = time.time()
    res = eng.run()
    dt = time.time() - t0

    certs = np.asarray(res.final_certificates)
    print(
        f"[TMSN-SGD] {res.rounds} rounds, {W} workers x {K} local steps"
        f"{' (worker 0 at 1/4 speed)' if args.laggard else ''}"
        f"{f' on a {cfg.mesh.size}-device mesh' if mesh is not None else ''}"
    )
    print(f"[TMSN-SGD] final certificates {[round(float(c), 4) for c in certs]}")
    print(
        f"[TMSN-SGD] {res.messages_sent} parameter broadcasts "
        f"({res.bytes_broadcast} bytes at {res.bytes_broadcast // max(res.messages_sent, 1)}"
        f" B/msg) instead of {res.rounds * K} gradient all-reduces ({dt:.1f}s)"
    )

    if not args.laggard and mesh is None:
        # uniform speed + delay 1 is the oracle-exact regime — show it
        orc = oracle_run(worker, W, args.rounds, eps=0.0, seed=0)
        gap = float(np.max(np.abs(certs - orc.certs)))
        print(f"[oracle  ] synchronous-exchange certificates match: gap {gap:.2e}")


if __name__ == "__main__":
    main()
