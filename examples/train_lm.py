"""End-to-end LM training driver: a ~100M-parameter member of the
yi/llama family (8 layers, d_model=768) trained for a few hundred steps
on synthetic tokens — the full production path (config -> model ->
optimizer -> pjit step -> checkpoint) at host scale.

  PYTHONPATH=src python examples/train_lm.py [--steps 200] [--tmsn]
"""

import argparse
import dataclasses
import time

import jax

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.data.tokens import TokenPipeline
from repro.launch.steps import make_train_step
from repro.models import init_params, param_count
from repro.optim import AdamWConfig, init_opt_state


def small_lm():
    """~100M-param reduced member of the yi-9b (llama/GQA) family."""
    return dataclasses.replace(
        get_config("yi-9b"),
        num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=2048, vocab=32000, head_dim=64,
        param_dtype="float32", compute_dtype="float32", remat=False,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--tmsn", action="store_true")
    ap.add_argument("--ckpt", default="/tmp/repro_lm_ckpt.npz")
    args = ap.parse_args()

    cfg = small_lm()
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    print(f"model: {param_count(params)/1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")
    opt_cfg = AdamWConfig(lr=6e-4)
    opt = init_opt_state(params, opt_cfg)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg), donate_argnums=(0, 1))

    pipe = TokenPipeline(batch=args.batch, seq=args.seq, vocab=cfg.vocab)
    t0 = time.time()
    first = last = None
    for step, batch in zip(range(args.steps), pipe):
        params, opt, metrics = step_fn(params, opt, batch)
        loss = float(metrics["loss"])
        first = first if first is not None else loss
        last = loss
        if step % 20 == 0:
            tok_s = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step:4d}  loss {loss:.4f}  ({tok_s:.0f} tok/s)", flush=True)
    print(f"loss {first:.4f} -> {last:.4f} over {args.steps} steps")
    save_checkpoint(args.ckpt, params)
    restored = load_checkpoint(args.ckpt, params)
    assert all(
        (a == b).all() for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored))
    )
    print(f"checkpoint round-trip OK -> {args.ckpt}")


if __name__ == "__main__":
    main()
